//! Sharded admission: N per-engine admission queues behind a
//! placement-aware router.
//!
//! One engine owns one thread (device buffers are not `Send` on either
//! substrate backend), so scaling past a single slot pool means N engine
//! SHARDS — each an owned thread holding its own `Substrate`, slot pool,
//! and gather/plan caches, draining its own [`Router`]. This module is
//! the engine-free front half: the [`ShardRouter`] decides WHICH shard's
//! queue an admission lands in; the engine-side half (shard threads,
//! event fan-in, metrics publication) lives in `server::sharded`.
//!
//! Placement rules, in order:
//!
//! 1. **Session affinity** — a request carrying a `session` key is
//!    placed on `hash(session) % n_shards` (FNV-1a, stable across runs
//!    and processes), so a client's requests share one shard's KV/gather
//!    locality. Affine requests never spill on backpressure (the home
//!    queue's `queue_full` is the honest answer) and are never moved by
//!    work stealing. If the home shard is down, the session falls back
//!    DETERMINISTICALLY to the next healthy shard ring-wise from its
//!    home index — every request of that session agrees on the same
//!    successor, so the fallback shard accumulates the session's warm
//!    state instead of the session scattering least-loaded per admit.
//! 2. **Prefix affinity** — when the engines run a prefix cache (the
//!    router is built `with_prefix_block`), a sessionless request whose
//!    prompt spans at least one full cache block prefers the shard
//!    recorded in the prefix directory for its first-block hash: the
//!    shard that most recently admitted a prompt with that opening
//!    block, and therefore the shard whose device-resident cache can
//!    splice it. Preference, not pinning — if the directory shard is
//!    full or shedding the request spills like any sessionless work,
//!    and the directory is re-pointed at wherever it lands.
//! 3. **Least-loaded** — sessionless requests go to the healthy shard
//!    with the smallest load (occupied slots + queue depth), lowest
//!    index winning ties (deterministic placement, testable). On
//!    `queue_full` they spill to the next-least-loaded healthy shard;
//!    only when EVERY healthy queue is full does admission fail, with
//!    the fleet-wide capacity in the error.
//! 4. **Work stealing** — after each admission (and on demand via
//!    [`ShardRouter::rebalance`]) idle shards steal queued work from the
//!    back of the deepest queue: only sessionless, cancel-unflagged
//!    requests whose prefix directory entry does NOT map to the victim
//!    move (stealing a prefix-affine request off the shard holding its
//!    cached KV would turn a warm hit into a cold prefill), and a moved
//!    request keeps its id and admission timestamp — stealing relocates
//!    work, it never re-admits it, so a request is admitted exactly
//!    once fleet-wide.
//!
//! Fault containment boundary: a poisoned shard (engine construction or
//! serve-loop failure) flips `healthy` off, retires its own queue with
//! `engine_error` events, and is skipped by placement from then on — the
//! rest of the fleet keeps serving. `rebalance` also evacuates any
//! request that raced into a dying shard's queue onto a healthy shard.
//! The supervisor (server side) may later [`Shard::revive`] a poisoned
//! shard with a fresh engine — it rejoins placement and stealing — or
//! [`Shard::park`] it permanently when the crash loop trips the circuit
//! breaker.
//!
//! Overload is handled at placement by a staged, SLO-aware controller
//! (see [`SloPolicy`]): under moderate pressure, prunable requests are
//! *down-kept* — snapped to a lower keep fraction, with the client's
//! original ask recorded in the response's `prune` provenance — and
//! under heavy pressure admission *sheds* with a retryable `overloaded`
//! error whose `retry_after_ms` scales with the backlog of the shard(s)
//! that actually refused the request — not the fleet sum, which would
//! let a busy-but-admitting peer inflate the backoff of a shed that it
//! took no part in. Dual enter/exit thresholds give the dial hysteresis
//! so it cannot flap on a noisy load signal.
//!
//! The controller stage is PER SHARD: the shared pooled-capacity
//! utilization term is max'd with each shard's OWN rolling-p99
//! TTFT/inter-token-latency terms (not a fleet max), and the stage is
//! evaluated against the shard an admission actually targets. One slow
//! shard therefore degrades or sheds only the traffic placed on it —
//! sessionless work spills past a shedding shard to a healthy one, and
//! only a session-affine request (pinned to its slow home) or a fleet
//! where EVERY target sheds sees the `overloaded` error.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::prefix_cache::first_block_hash;
use crate::coordinator::router::{AdmitError, Router};
use crate::coordinator::sequence::{GenRequest, RequestId, ScoreRequest};
use crate::coordinator::types::Mode;
use crate::metrics::MetricsRegistry;

/// Steal only when the victim has at least this many queued requests —
/// a queue of one is about to be drained by its own engine anyway.
const STEAL_MIN_DEPTH: usize = 2;

/// How many recently-cancelled request ids the router remembers for the
/// cancel-after-steal closure (see [`ShardRouter::request_cancel`]).
const CANCEL_RING_CAPACITY: usize = 256;

/// Bound on the prefix directory (first-block hash → shard). Oldest
/// entries fall out first; a dropped entry only costs a cold prefill on
/// the next reuse, so a small bound is safe.
const PREFIX_DIRECTORY_CAPACITY: usize = 1024;

/// One engine shard's admission-side state. The engine thread publishes
/// its load (`slots_busy`) every serve-loop iteration and its metrics
/// registry once at construction; everything else is written by the
/// placement side.
pub struct Shard {
    pub index: usize,
    pub router: Arc<Router>,
    slots_busy: AtomicU64,
    slots_total: AtomicU64,
    healthy: AtomicBool,
    /// times the supervisor rebuilt this shard's engine after a crash
    restarts: AtomicU64,
    /// circuit breaker tripped: the supervisor gave up respawning this
    /// shard (repeated crashes inside the failure window); stays down
    parked: AtomicBool,
    /// when the current incarnation came up (boot or last respawn)
    since: Mutex<Instant>,
    /// the shard engine's metrics registry, published by the shard
    /// thread once its engine exists (None while booting / when
    /// construction failed)
    metrics: Mutex<Option<Arc<MetricsRegistry>>>,
}

impl Shard {
    fn new(index: usize, capacity: usize, max_prompt: usize) -> Shard {
        Shard {
            index,
            router: Arc::new(Router::new(capacity, max_prompt)),
            slots_busy: AtomicU64::new(0),
            slots_total: AtomicU64::new(0),
            healthy: AtomicBool::new(true),
            restarts: AtomicU64::new(0),
            parked: AtomicBool::new(false),
            since: Mutex::new(Instant::now()),
            metrics: Mutex::new(None),
        }
    }

    /// Placement load: occupied decode slots + queued admissions.
    pub fn load(&self) -> u64 {
        self.slots_busy.load(Ordering::Relaxed)
            + self.router.len() as u64
    }

    pub fn slots_busy(&self) -> u64 {
        self.slots_busy.load(Ordering::Relaxed)
    }

    pub fn slots_total(&self) -> u64 {
        self.slots_total.load(Ordering::Relaxed)
    }

    /// Engine-thread heartbeat: publish the shard's occupancy for the
    /// placement side (called every serve-loop iteration).
    pub fn publish_load(&self, busy: u64, total: u64) {
        self.slots_busy.store(busy, Ordering::Relaxed);
        self.slots_total.store(total, Ordering::Relaxed);
    }

    /// Publish the shard engine's metrics registry (shard thread, once).
    pub fn publish_metrics(&self, m: Arc<MetricsRegistry>) {
        *self.metrics.lock().unwrap() = Some(m);
    }

    pub fn metrics(&self) -> Option<Arc<MetricsRegistry>> {
        self.metrics.lock().unwrap().clone()
    }

    pub fn is_healthy(&self) -> bool {
        self.healthy.load(Ordering::Relaxed)
    }

    /// Mark the shard poisoned (engine construction or serve-loop
    /// failure). Placement skips it from here on; the caller is
    /// responsible for retiring whatever its queue still holds.
    pub fn poison(&self) {
        self.healthy.store(false, Ordering::Relaxed);
    }

    /// How many times the supervisor respawned this shard's engine.
    pub fn restarts(&self) -> u64 {
        self.restarts.load(Ordering::Relaxed)
    }

    /// Circuit breaker tripped: the supervisor stopped respawning this
    /// shard. Parked implies poisoned; `health` reports the two states
    /// separately so operators can tell "respawning" from "gave up".
    pub fn is_parked(&self) -> bool {
        self.parked.load(Ordering::Relaxed)
    }

    /// Trip the circuit breaker: the shard leaves placement permanently
    /// (until an operator restarts the process).
    pub fn park(&self) {
        self.parked.store(true, Ordering::Relaxed);
        self.healthy.store(false, Ordering::Relaxed);
    }

    /// Supervisor respawn: a fresh engine serves this shard again. It
    /// rejoins placement and stealing, the restart count bumps, and the
    /// incarnation clock restarts.
    pub fn revive(&self) {
        *self.since.lock().unwrap() = Instant::now();
        self.restarts.fetch_add(1, Ordering::Relaxed);
        self.parked.store(false, Ordering::Relaxed);
        self.healthy.store(true, Ordering::Relaxed);
    }

    /// Seconds since this shard's current incarnation came up.
    pub fn uptime_secs(&self) -> u64 {
        self.since.lock().unwrap().elapsed().as_secs()
    }
}

/// Staged overload state of the SLO-aware admission controller.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pressure {
    /// serve everything as requested
    Nominal,
    /// down-keep prunable work to the degraded keep cap
    Degrade,
    /// shed new work with a retryable `overloaded` error
    Shed,
}

/// Tunables for the staged admission controller.
///
/// The controller watches a scalar pressure signal per shard: fleet
/// utilization (occupied slots + queued admissions over total slots +
/// queue capacity — capacity is pooled because spilling and stealing
/// move sessionless work freely) max'd with THAT SHARD's rolling-p99
/// TTFT / inter-token-latency terms, scaled so a p99 AT the SLO reads
/// as shed-worthy pressure. Each stage has separate enter/exit
/// thresholds (enter > exit) so the dial holds its state in the band
/// between them instead of flapping.
#[derive(Clone, Copy, Debug)]
pub struct SloPolicy {
    /// Nominal → Degrade when pressure reaches this
    pub degrade_enter: f64,
    /// back to Nominal only when pressure falls below this
    pub degrade_exit: f64,
    /// Degrade → Shed when pressure reaches this
    pub shed_enter: f64,
    /// Shed → Degrade only when pressure falls below this
    pub shed_exit: f64,
    /// p99 time-to-first-token SLO (µs)
    pub ttft_slo_us: f64,
    /// p99 inter-token-latency SLO (µs)
    pub itl_slo_us: f64,
    /// keep fraction prunable requests snap to under Degrade
    pub degraded_keep: f64,
}

impl Default for SloPolicy {
    fn default() -> SloPolicy {
        SloPolicy {
            degrade_enter: 0.50,
            degrade_exit: 0.35,
            shed_enter: 0.85,
            shed_exit: 0.70,
            // generous latency SLOs: on the CPU reference substrate the
            // utilization term dominates; real deployments tighten these
            ttft_slo_us: 10_000_000.0,
            itl_slo_us: 2_000_000.0,
            degraded_keep: 0.5,
        }
    }
}

/// Placement-aware admission front for N engine shards. Thread-safe:
/// server handler threads admit concurrently; shard engine threads only
/// drain their own `Router` and publish load/health.
pub struct ShardRouter {
    shards: Vec<Arc<Shard>>,
    next_id: AtomicU64,
    /// requests moved between shards by work stealing (fleet counter)
    stolen: AtomicU64,
    /// staged-admission tunables (fixed at construction)
    slo: SloPolicy,
    /// per-shard controller stage, advanced when an admission evaluates
    /// that shard as a target (one slow shard's latency breach must not
    /// degrade traffic placed on its healthy peers)
    pressure: Mutex<Vec<Pressure>>,
    /// recently-cancelled ids (bounded ring). A cancel flag drained by a
    /// shard BEFORE a steal delivers the request there is lost (flags
    /// drain once per tick); re-flagging from this ring after every
    /// cross-shard move closes that race.
    recent_cancels: Mutex<VecDeque<RequestId>>,
    /// prefix-cache block size the engines run with (0 = off). Atomic
    /// because the engines exist only after their shard threads boot:
    /// the first ready shard publishes the block, flipping placement
    /// rule 2 on for every admission after it
    prefix_block: AtomicU64,
    /// first-block hash → shard that last admitted a prompt opening
    /// with that block (map + insertion ring for the size bound)
    prefix_dir: Mutex<PrefixDirectory>,
}

#[derive(Default)]
struct PrefixDirectory {
    map: HashMap<u64, usize>,
    ring: VecDeque<u64>,
}

/// FNV-1a, the session-placement hash. Stable across runs, processes,
/// and builds — a session key maps to the same home shard for the
/// lifetime of a deployment at fixed shard count.
fn fnv1a(key: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl ShardRouter {
    /// `capacity` and `max_prompt` apply PER SHARD (each shard's Router
    /// keeps its own bounded queue; fleet capacity is the sum).
    pub fn new(n_shards: usize, capacity: usize, max_prompt: usize)
               -> ShardRouter {
        assert!(n_shards >= 1, "at least one shard");
        ShardRouter {
            shards: (0..n_shards)
                .map(|i| Arc::new(Shard::new(i, capacity, max_prompt)))
                .collect(),
            next_id: AtomicU64::new(1),
            stolen: AtomicU64::new(0),
            slo: SloPolicy::default(),
            pressure: Mutex::new(vec![Pressure::Nominal; n_shards]),
            recent_cancels: Mutex::new(VecDeque::new()),
            prefix_block: AtomicU64::new(0),
            prefix_dir: Mutex::new(PrefixDirectory::default()),
        }
    }

    /// Enable prefix-affine placement (builder style; tests). The
    /// server publishes the block post-construction with
    /// [`ShardRouter::set_prefix_block`] once an engine exists.
    pub fn with_prefix_block(self, block: Option<usize>) -> ShardRouter {
        self.set_prefix_block(block);
        self
    }

    /// Publish the prefix-cache block size the shard engines run with,
    /// so placement hashes prompt opening blocks exactly the way the
    /// engine caches do. `None` (or zero) leaves the directory off.
    pub fn set_prefix_block(&self, block: Option<usize>) {
        self.prefix_block
            .store(block.unwrap_or(0) as u64, Ordering::Relaxed);
    }

    fn prefix_block(&self) -> Option<usize> {
        match self.prefix_block.load(Ordering::Relaxed) {
            0 => None,
            b => Some(b as usize),
        }
    }

    /// Replace the admission-controller tunables (builder style; used by
    /// tests and load harnesses that need tighter SLOs than the
    /// defaults).
    pub fn with_slo(mut self, slo: SloPolicy) -> ShardRouter {
        self.slo = slo;
        self
    }

    /// The most severe controller stage across the shards (telemetry /
    /// tests; single-shard fleets read exactly their shard's stage).
    pub fn pressure(&self) -> Pressure {
        let st = self.pressure.lock().unwrap();
        if st.contains(&Pressure::Shed) {
            Pressure::Shed
        } else if st.contains(&Pressure::Degrade) {
            Pressure::Degrade
        } else {
            Pressure::Nominal
        }
    }

    /// One shard's controller stage (telemetry / tests).
    pub fn shard_pressure(&self, i: usize) -> Pressure {
        self.pressure.lock().unwrap()[i]
    }

    /// Pooled-capacity utilization over the healthy shards. Shared
    /// across the per-shard signals — spilling and stealing move
    /// sessionless work freely, so free capacity anywhere absorbs
    /// backlog anywhere; capacity that placement cannot reach
    /// (unhealthy shards) is not capacity.
    fn utilization(&self) -> f64 {
        let (mut busy, mut slots) = (0u64, 0u64);
        let (mut queued, mut cap) = (0usize, 0usize);
        for s in &self.shards {
            if !s.is_healthy() {
                continue;
            }
            busy += s.slots_busy();
            slots += s.slots_total();
            queued += s.router.len();
            cap += s.router.capacity;
        }
        let denom = (slots as usize + cap).max(1) as f64;
        (busy as usize + queued) as f64 / denom
    }

    /// One shard's overload signal: pooled utilization max'd with the
    /// shard's OWN SLO-relative rolling-p99 latency terms. Latency is
    /// deliberately not pooled and not fleet-max'd: a p99 breach on one
    /// shard is that shard's serving problem — its peers are still
    /// meeting the SLO and must keep admitting at full keep.
    fn shard_signal(&self, util: f64, shard: &Shard) -> f64 {
        util.max(self.latency_term(shard))
    }

    /// The shard's SLO-relative latency pressure alone: rolling-p99
    /// TTFT/ITL over their SLOs, scaled so a p99 AT the SLO maps
    /// straight onto the shed threshold — breaching latency sheds even
    /// when utilization alone looks fine.
    fn latency_term(&self, shard: &Shard) -> f64 {
        let Some(m) = shard.metrics() else { return 0.0 };
        let ttft = m.ttft.percentile_us(99.0) / self.slo.ttft_slo_us;
        let itl = m.inter_token_latency.percentile_us(99.0)
            / self.slo.itl_slo_us;
        ttft.max(itl) * self.slo.shed_enter
    }

    /// Advance one shard's staged controller (dual-threshold
    /// hysteresis) and return the stage an admission targeting it must
    /// apply. `util` is the shared pooled-utilization term, computed
    /// once per admission and reused across the spill candidates.
    fn eval_pressure_for(&self, i: usize, util: f64) -> Pressure {
        let sig = self.shard_signal(util, &self.shards[i]);
        let mut all = self.pressure.lock().unwrap();
        let st = &mut all[i];
        *st = match *st {
            Pressure::Nominal if sig >= self.slo.shed_enter => {
                Pressure::Shed
            }
            Pressure::Nominal if sig >= self.slo.degrade_enter => {
                Pressure::Degrade
            }
            Pressure::Nominal => Pressure::Nominal,
            Pressure::Degrade if sig >= self.slo.shed_enter => {
                Pressure::Shed
            }
            Pressure::Degrade if sig < self.slo.degrade_exit => {
                Pressure::Nominal
            }
            Pressure::Degrade => Pressure::Degrade,
            Pressure::Shed if sig < self.slo.degrade_exit => {
                Pressure::Nominal
            }
            Pressure::Shed if sig < self.slo.shed_exit => {
                Pressure::Degrade
            }
            Pressure::Shed => Pressure::Shed,
        };
        *st
    }

    /// Deterministic client backoff hint for a shed admission: scales
    /// with the backlog of the shard(s) that actually refused this
    /// request, clamped to a sane band. Shed is a per-shard decision,
    /// so the hint must be too — summing the fleet's queues would let a
    /// busy-but-admitting peer (whose backlog this client will never
    /// wait behind) inflate the backoff. The least-backlogged refuser
    /// bounds the wait: that is the first queue a retry could land in.
    fn retry_after_ms(&self, refusing: &[usize]) -> u64 {
        let depth = refusing
            .iter()
            .map(|&i| self.shards[i].router.len())
            .min()
            .unwrap_or(0);
        (50 + 20 * depth as u64).min(2_000)
    }

    /// The first-block hash that keys prefix-affine placement for this
    /// request, when the directory is on and the prompt is long enough
    /// to benefit (a cache hit needs a strict prefix, so a prompt of
    /// one block or less never splices — don't pin it anywhere).
    fn prefix_hash(&self, req: &GenRequest) -> Option<u64> {
        let block = self.prefix_block()?;
        if req.prompt.len() <= block {
            return None;
        }
        first_block_hash(&req.prompt, block)
    }

    /// Directory shard for a first-block hash, if it is still in
    /// placement (a poisoned shard's cache died with its engine — the
    /// stale entry is ignored and re-pointed on the next admission).
    fn prefix_lookup(&self, hash: u64) -> Option<usize> {
        self.prefix_dir
            .lock()
            .unwrap()
            .map
            .get(&hash)
            .copied()
            .filter(|&i| self.shards[i].is_healthy())
    }

    /// Point a first-block hash at the shard that just admitted it.
    fn prefix_record(&self, hash: u64, shard: usize) {
        let mut dir = self.prefix_dir.lock().unwrap();
        if dir.map.insert(hash, shard).is_none() {
            dir.ring.push_back(hash);
            if dir.ring.len() > PREFIX_DIRECTORY_CAPACITY {
                if let Some(old) = dir.ring.pop_front() {
                    dir.map.remove(&old);
                }
            }
        }
    }

    /// Whether stealing this request off `shard` would strand it away
    /// from its cached prefix.
    fn prefix_pinned_to(&self, req: &GenRequest, shard: usize) -> bool {
        self.prefix_hash(req).and_then(|h| self.prefix_lookup(h))
            == Some(shard)
    }

    /// Deterministic fallback order for a session whose home shard is
    /// out of placement: healthy shards ring-wise from the home index.
    /// Every admission of the session computes the same ring, so they
    /// all land on the same successor (given stable health states) and
    /// the session's locality re-forms there — instead of scattering
    /// across the fleet as each admit chases the load snapshot.
    fn successors(&self, home: usize) -> Vec<usize> {
        (1..self.shards.len())
            .map(|k| (home + k) % self.shards.len())
            .filter(|&i| self.shards[i].is_healthy())
            .collect()
    }

    /// Degrade stage: snap a prunable request's keep fraction down to
    /// the policy cap, recording the client's original ask for response
    /// provenance. `Full` requests pass untouched — there is no keep
    /// axis to degrade; they are only affected at the Shed stage.
    /// Returns whether the request was actually down-kept.
    fn downkeep(&self, req: &mut GenRequest) -> bool {
        let cap = self.slo.degraded_keep;
        let keep = match &mut req.mode {
            Mode::Griffin { keep, .. }
            | Mode::Magnitude { keep }
            | Mode::Wanda { keep } => keep,
            Mode::Full => return false,
        };
        if *keep <= cap {
            return false;
        }
        if req.keep_requested.is_none() {
            req.keep_requested = Some(*keep);
        }
        *keep = cap;
        true
    }

    /// Cancel-after-steal closure: if the moved id was cancelled
    /// recently, re-flag it on its new home (cancels are idempotent, so
    /// over-flagging is harmless).
    fn reflag_if_cancelled(&self, shard: &Shard, id: RequestId) {
        if self.recent_cancels.lock().unwrap().contains(&id) {
            shard.router.request_cancel(id);
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[Arc<Shard>] {
        &self.shards
    }

    pub fn shard(&self, i: usize) -> &Arc<Shard> {
        &self.shards[i]
    }

    /// Fleet-unique request ids (per-shard Routers never assign their
    /// own: admission hands them pre-stamped ids, which `Router::admit`
    /// preserves).
    pub fn fresh_id(&self) -> RequestId {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    pub fn stolen(&self) -> u64 {
        self.stolen.load(Ordering::Relaxed)
    }

    /// A session key's home shard (placement rule 1).
    pub fn home_shard(&self, session: &str) -> usize {
        (fnv1a(session) % self.shards.len() as u64) as usize
    }

    /// Healthy shard indices ordered by ascending load, ties broken by
    /// lowest index (`sort_by_key` is stable over the index-ordered
    /// iteration, so placement is deterministic given a load snapshot).
    fn healthy_by_load(&self) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.shards.len())
            .filter(|&i| self.shards[i].is_healthy())
            .collect();
        order.sort_by_key(|&i| self.shards[i].load());
        order
    }

    /// The shard `admit` would try first for this request — exposed so
    /// tests (and the server's streaming path) can reason about
    /// placement without admitting.
    pub fn place(&self, req: &GenRequest) -> Option<usize> {
        if let Some(key) = &req.session {
            let home = self.home_shard(key);
            if self.shards[home].is_healthy() {
                return Some(home);
            }
            return self.successors(home).into_iter().next();
        }
        if let Some(i) = self.prefix_hash(req)
            .and_then(|h| self.prefix_lookup(h))
        {
            return Some(i);
        }
        self.healthy_by_load().into_iter().next()
    }

    /// Admit a generate request somewhere in the fleet. Returns the
    /// fleet-unique id and the shard index that took it. Validation
    /// errors are terminal; `queue_full` spills sessionless requests
    /// across every healthy shard before giving up with the fleet-wide
    /// capacity.
    pub fn admit(&self, mut req: GenRequest)
                 -> Result<(RequestId, usize), AdmitError> {
        if req.id == 0 {
            req.id = self.fresh_id();
        }
        // session affinity outranks prefix affinity: a session already
        // owns a home with its KV locality, the directory only guides
        // sessionless work toward warm caches
        let prefix = match &req.session {
            Some(_) => None,
            None => self.prefix_hash(&req),
        };
        let targets: Vec<usize> = match &req.session {
            Some(key) => {
                let home = self.home_shard(key);
                if self.shards[home].is_healthy() {
                    // affine requests do not spill: the home queue's
                    // backpressure is the honest answer
                    vec![home]
                } else {
                    // home engine (and its session locality) is gone;
                    // fall back deterministically so the session
                    // re-forms on ONE successor (placement rule 1)
                    self.successors(home)
                }
            }
            None => {
                let mut order = self.healthy_by_load();
                if let Some(i) =
                    prefix.and_then(|h| self.prefix_lookup(h))
                {
                    // prefix affinity: try the shard holding the
                    // cached prefix first, spill least-loaded after
                    order.retain(|&j| j != i);
                    order.insert(0, i);
                }
                order
            }
        };
        if targets.is_empty() {
            return Err(AdmitError::NoHealthyShards);
        }
        // staged overload control runs per TARGET shard: shed is the
        // last resort, down-keep buys capacity first (audited in the
        // response's prune provenance), and a shedding shard is skipped
        // the way a full queue is — sessionless work spills to a
        // healthy peer, only affine work eats its slow home's refusal
        let util = self.utilization();
        let mut refusing: Vec<usize> = Vec::new();
        let mut all_shed = true;
        for &i in &targets {
            let shard = &self.shards[i];
            let mut downkept = false;
            match self.eval_pressure_for(i, util) {
                Pressure::Nominal => {}
                Pressure::Degrade => downkept = self.downkeep(&mut req),
                Pressure::Shed => {
                    refusing.push(i);
                    continue;
                }
            }
            all_shed = false;
            match shard.router.admit(req.clone()) {
                Ok(id) => {
                    // close the admit/poison race: if the shard died
                    // between the health check and the push, pull the
                    // request back and re-place it. A failed pull means
                    // the dying shard's final drain owns it and will
                    // emit its engine_error — either way it is handled
                    // exactly once.
                    if !shard.is_healthy() {
                        if let Some(r) = shard.router.remove_queued(id) {
                            return self.admit(r);
                        }
                    }
                    if downkept {
                        if let Some(m) = shard.metrics() {
                            m.requests_downkept.inc();
                        }
                    }
                    if let Some(h) = prefix {
                        self.prefix_record(h, i);
                    }
                    self.reflag_if_cancelled(shard, id);
                    self.rebalance();
                    return Ok((id, i));
                }
                Err(AdmitError::QueueFull { .. }) => continue,
                Err(e) => return Err(e),
            }
        }
        if all_shed {
            // every shard this request could land on is shedding — only
            // now is `overloaded` the honest fleet-level answer, with
            // the backoff derived from the refusers' own backlogs
            return Err(AdmitError::Overloaded {
                retry_after_ms: self.retry_after_ms(&refusing),
            });
        }
        Err(AdmitError::QueueFull { capacity: self.capacity() })
    }

    /// Admit a score request (least-loaded placement; scores carry no
    /// session key and are never stolen — they run synchronously off
    /// the owning shard's queue).
    pub fn admit_score(&self, mut req: ScoreRequest)
                       -> Result<(RequestId, usize), AdmitError> {
        if req.id == 0 {
            req.id = self.fresh_id();
        }
        let targets = self.healthy_by_load();
        if targets.is_empty() {
            return Err(AdmitError::NoHealthyShards);
        }
        // scores have no keep axis to degrade, but they are
        // work-bearing and a shedding shard refuses them like anything
        // else — they just spill past it to a healthy peer first
        let util = self.utilization();
        let mut refusing: Vec<usize> = Vec::new();
        let mut all_shed = true;
        for &i in &targets {
            if self.eval_pressure_for(i, util) == Pressure::Shed {
                refusing.push(i);
                continue;
            }
            all_shed = false;
            match self.shards[i].router.admit_score(req.clone()) {
                Ok(id) => return Ok((id, i)),
                Err(AdmitError::QueueFull { .. }) => continue,
                Err(e) => return Err(e),
            }
        }
        if all_shed {
            return Err(AdmitError::Overloaded {
                retry_after_ms: self.retry_after_ms(&refusing),
            });
        }
        Err(AdmitError::QueueFull { capacity: self.capacity() })
    }

    /// Flag a cancel on every shard: the owning shard resolves it
    /// (queued request dropped / slot retired) and the rest drain it as
    /// a no-op — fan-out avoids tracking request→shard ownership, which
    /// work stealing would invalidate anyway.
    pub fn request_cancel(&self, id: RequestId) {
        {
            let mut ring = self.recent_cancels.lock().unwrap();
            if ring.len() == CANCEL_RING_CAPACITY {
                ring.pop_front();
            }
            ring.push_back(id);
        }
        for s in &self.shards {
            s.router.request_cancel(id);
        }
    }

    /// Wake every shard's parked engine thread (shutdown).
    pub fn wake_all(&self) {
        for s in &self.shards {
            s.router.wake_all();
        }
    }

    /// One stealing pass (also run after every sessionless admission):
    /// while some healthy shard is fully idle and another healthy
    /// shard's queue is deep, move the deep queue's newest sessionless
    /// request to the idle shard — skipping requests whose prefix
    /// directory entry maps to the victim (their cached prefix lives
    /// there; moving them trades a warm splice for a cold prefill). A shard whose own latency signal
    /// reads shed-worthy never steals — placement just routed work
    /// around it, and stealing it back would undo the per-shard SLO
    /// isolation. Also evacuates anything stranded in a poisoned
    /// shard's queue (affinity included — the home engine is gone).
    /// Returns how many requests moved.
    pub fn rebalance(&self) -> usize {
        let mut moved = 0;
        // evacuation: a request that raced into a queue after its shard
        // died would otherwise never be drained
        for victim in &self.shards {
            if victim.is_healthy() {
                continue;
            }
            while let Some(r) = victim.router.steal_newest(|_| true) {
                match self.admit_evacuated(r) {
                    Some(_) => moved += 1,
                    None => break, // nowhere to go; final drain owns it
                }
            }
        }
        // idle-steals-from-deep
        loop {
            let Some(thief) = self.shards.iter().find(|s| {
                s.is_healthy()
                    && s.load() == 0
                    && self.latency_term(s) < self.slo.shed_enter
            }) else {
                break;
            };
            let Some(victim) = self
                .shards
                .iter()
                .filter(|s| {
                    s.is_healthy() && s.router.len() >= STEAL_MIN_DEPTH
                })
                .max_by_key(|s| s.router.len())
            else {
                break;
            };
            let Some(r) = victim.router.steal_newest(|r| {
                r.session.is_none()
                    && !self.prefix_pinned_to(r, victim.index)
            }) else {
                break; // deep queue is all affine work (session- or
                       // prefix-pinned to the victim's warm cache)
            };
            let id = r.id;
            thief.router.push_stolen(r);
            self.reflag_if_cancelled(thief, id);
            self.stolen.fetch_add(1, Ordering::Relaxed);
            moved += 1;
        }
        moved
    }

    /// Re-home a request evacuated from a poisoned shard. Preserves id
    /// and admission timestamp (like stealing, this moves work).
    fn admit_evacuated(&self, req: GenRequest) -> Option<usize> {
        let order = self.healthy_by_load();
        let i = *order.first()?;
        let id = req.id;
        self.shards[i].router.push_stolen(req);
        self.reflag_if_cancelled(&self.shards[i], id);
        Some(i)
    }

    /// Fleet generate-queue depth (sum over shards).
    pub fn queue_depth(&self) -> usize {
        self.shards.iter().map(|s| s.router.len()).sum()
    }

    /// Fleet score-queue depth.
    pub fn score_depth(&self) -> usize {
        self.shards.iter().map(|s| s.router.score_len()).sum()
    }

    /// Fleet queue capacity (sum over shards).
    pub fn capacity(&self) -> usize {
        self.shards.iter().map(|s| s.router.capacity).sum()
    }

    pub fn healthy_count(&self) -> usize {
        self.shards.iter().filter(|s| s.is_healthy()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::types::Mode;

    fn req() -> GenRequest {
        let mut r = GenRequest::greedy(0, vec![1, 2, 3], 4, Mode::Full);
        r.id = 0;
        r
    }

    fn sreq(key: &str) -> GenRequest {
        let mut r = req();
        r.session = Some(key.to_string());
        r
    }

    #[test]
    fn least_loaded_tie_breaks_deterministically() {
        let sr = ShardRouter::new(4, 8, 128);
        // all empty: lowest index wins the tie
        assert_eq!(sr.place(&req()), Some(0));
        // load shard 0 and 1; 2 is now the least-loaded
        sr.shard(0).publish_load(3, 4);
        sr.shard(1).publish_load(1, 4);
        assert_eq!(sr.place(&req()), Some(2));
        // equal loads tie-break low again
        sr.shard(2).publish_load(1, 4);
        sr.shard(3).publish_load(1, 4);
        assert_eq!(sr.place(&req()), Some(1));
        // queue depth counts toward load
        let (_, at) = sr.admit(req()).unwrap();
        assert_eq!(at, 1);
        assert_eq!(sr.place(&req()), Some(2), "queued work adds load");
    }

    #[test]
    fn session_affinity_is_stable() {
        let sr = ShardRouter::new(4, 64, 128);
        let home = sr.home_shard("user-42");
        // same key, many admissions, same shard every time — even when
        // other shards are idle and the home shard is loaded
        sr.shard(home).publish_load(4, 4);
        for _ in 0..10 {
            let (_, at) = sr.admit(sreq("user-42")).unwrap();
            assert_eq!(at, home, "affine placement must not follow load");
        }
        // stability under a shard-count-preserving rebalance: stealing
        // must never move affine work off its home shard
        let moved = sr.rebalance();
        assert_eq!(moved, 0, "affine queue must not be rebalanced");
        assert_eq!(sr.shard(home).router.len(), 10);
        // a different key may land elsewhere, but is itself stable
        let other = sr.home_shard("user-7");
        assert_eq!(sr.home_shard("user-7"), other);
    }

    #[test]
    fn fnv_hash_is_fixed() {
        // placement is part of the deployment contract: a session key's
        // home shard must survive process restarts. Pin the hash.
        assert_eq!(super::fnv1a(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(super::fnv1a("a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn stealing_moves_work_without_double_admission() {
        let sr = ShardRouter::new(2, 64, 128);
        // pin shard 1 busier than shard 0 can get, so least-loaded
        // deep-queues shard 0
        sr.shard(1).publish_load(8, 8);
        let mut ids = Vec::new();
        for _ in 0..6 {
            let (id, at) = sr.admit(req()).unwrap();
            assert_eq!(at, 0);
            ids.push(id);
        }
        assert_eq!(sr.shard(0).router.len(), 6);
        // shard 1 goes idle: the next rebalance steals from shard 0
        sr.shard(1).publish_load(0, 4);
        let moved = sr.rebalance();
        assert!(moved >= 1, "idle shard must steal from the deep queue");
        assert_eq!(sr.stolen(), moved as u64);
        // exactly-once: every id is in exactly one queue, none dropped,
        // none duplicated
        let mut seen: Vec<u64> = Vec::new();
        for s in sr.shards() {
            while let Some(r) = s.router.steal_newest(|_| true) {
                seen.push(r.id);
            }
        }
        seen.sort_unstable();
        let mut want = ids.clone();
        want.sort_unstable();
        assert_eq!(seen, want, "steal must neither drop nor duplicate");
    }

    #[test]
    fn stealing_skips_cancel_flagged_requests() {
        let sr = ShardRouter::new(2, 64, 128);
        sr.shard(1).publish_load(4, 4);
        let (a, _) = sr.admit(req()).unwrap();
        let (b, _) = sr.admit(req()).unwrap();
        // flag the newest request; the steal must take the other one
        sr.request_cancel(b);
        sr.shard(1).publish_load(0, 4);
        assert!(sr.rebalance() >= 1);
        let got = sr.shard(1).router.steal_newest(|_| true).unwrap();
        assert_eq!(got.id, a, "flagged request must stay on its shard");
        assert_eq!(sr.shard(0).router.len(), 1);
    }

    /// Admission-controller policy that never degrades or sheds, for
    /// tests exercising the queue-capacity path in isolation (with the
    /// default policy, shedding pre-empts `queue_full` for sessionless
    /// work well before the queues fill).
    fn no_shed() -> SloPolicy {
        SloPolicy {
            degrade_enter: 10.0,
            degrade_exit: 9.0,
            shed_enter: 20.0,
            shed_exit: 19.0,
            ..SloPolicy::default()
        }
    }

    #[test]
    fn queue_full_spills_then_sums_capacity() {
        let sr = ShardRouter::new(2, 2, 128).with_slo(no_shed());
        // fill both shards (capacity 2 each). Least-loaded alternates,
        // and once one queue is full, spilling finds the other.
        for _ in 0..4 {
            sr.admit(req()).unwrap();
        }
        let e = sr.admit(req()).unwrap_err();
        match e {
            AdmitError::QueueFull { capacity } => {
                assert_eq!(capacity, 4, "error reports FLEET capacity");
            }
            other => panic!("expected QueueFull, got {other:?}"),
        }
        // both queues actually hold their share (no shard over cap)
        assert_eq!(sr.shard(0).router.len(), 2);
        assert_eq!(sr.shard(1).router.len(), 2);
        // affine requests do NOT spill: their home queue full is final
        let key = "sticky";
        let home = sr.home_shard(key);
        let e = sr.admit(sreq(key)).unwrap_err();
        assert!(matches!(e, AdmitError::QueueFull { .. }));
        assert_eq!(
            sr.shard(1 - home).router.len(),
            2,
            "affine overflow must not leak onto the other shard"
        );
    }

    #[test]
    fn ids_are_fleet_unique() {
        let sr = ShardRouter::new(3, 64, 128);
        let mut ids = std::collections::HashSet::new();
        for i in 0..30 {
            let (id, _) = if i % 2 == 0 {
                sr.admit(req()).unwrap()
            } else {
                sr.admit(sreq(&format!("s{i}"))).unwrap()
            };
            assert!(ids.insert(id), "duplicate id {id}");
        }
    }

    #[test]
    fn poisoned_shard_is_skipped_and_evacuated() {
        let sr = ShardRouter::new(2, 64, 128);
        // find a session homed on shard 0
        let key = (0..100)
            .map(|i| format!("s{i}"))
            .find(|k| sr.home_shard(k) == 0)
            .unwrap();
        sr.admit(req()).unwrap(); // lands on shard 0 (tie-break)
        assert_eq!(sr.shard(0).router.len(), 1);
        sr.shard(0).poison();
        assert_eq!(sr.healthy_count(), 1);
        // affine-to-dead-home falls back to a healthy shard
        let (_, at) = sr.admit(sreq(&key)).unwrap();
        assert_eq!(at, 1, "dead home shard must not take admissions");
        // the stranded request was evacuated to shard 1 by the
        // admission's rebalance pass
        assert_eq!(sr.shard(0).router.len(), 0, "evacuated");
        assert_eq!(sr.shard(1).router.len(), 2);
        // all shards down: honest terminal error
        sr.shard(1).poison();
        assert!(matches!(
            sr.admit(req()),
            Err(AdmitError::NoHealthyShards)
        ));
        assert!(matches!(
            sr.admit_score(ScoreRequest {
                id: 0,
                prompt: vec![1],
                continuation: vec![2],
                mode: Mode::Full,
                admitted_at: std::time::Instant::now(),
            }),
            Err(AdmitError::NoHealthyShards)
        ));
    }

    #[test]
    fn cancel_fans_out_to_every_shard() {
        let sr = ShardRouter::new(3, 64, 128);
        let (id, at) = sr.admit(req()).unwrap();
        sr.request_cancel(id);
        for (i, s) in sr.shards().iter().enumerate() {
            let flags = s.router.take_cancelled();
            assert_eq!(flags, vec![id], "shard {i} must see the flag");
        }
        // the owning shard resolves it; the others no-op
        assert!(sr.shard(at).router.remove_queued(id).is_some());
    }

    fn gr(keep: f64) -> GenRequest {
        let mut r = req();
        r.mode = Mode::griffin(keep);
        r
    }

    #[test]
    fn staged_admission_downkeeps_then_sheds_then_recovers() {
        let sr = ShardRouter::new(1, 10, 128);
        // empty queue: nominal, keep served exactly as requested
        let (first, _) = sr.admit(gr(0.75)).unwrap();
        // fill to depth 5 with unprunable work (utilization 0.5)
        for _ in 0..4 {
            sr.admit(req()).unwrap();
        }
        // pressure crossed degrade_enter: this admission is down-kept,
        // with the original ask preserved for provenance
        let (degraded, _) = sr.admit(gr(0.75)).unwrap();
        assert_eq!(sr.pressure(), Pressure::Degrade);
        // Full-mode work has no keep axis and passes Degrade untouched
        for _ in 0..3 {
            sr.admit(req()).unwrap();
        }
        // depth 9 of 10: the next admission sees shed-worthy pressure
        let e = sr.admit(gr(0.9)).unwrap_err();
        match e {
            AdmitError::Overloaded { retry_after_ms } => {
                assert!(retry_after_ms >= 50, "useful backoff hint");
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert_eq!(sr.pressure(), Pressure::Shed);
        // at Shed even unprunable and score work is refused
        assert!(matches!(
            sr.admit(req()),
            Err(AdmitError::Overloaded { .. })
        ));
        assert!(matches!(
            sr.admit_score(ScoreRequest {
                id: 0,
                prompt: vec![1],
                continuation: vec![2],
                mode: Mode::Full,
                admitted_at: std::time::Instant::now(),
            }),
            Err(AdmitError::Overloaded { .. })
        ));
        // shed never dropped admitted work: everything is still queued
        let mut drained = Vec::new();
        while let Some(r) = sr.shard(0).router.steal_newest(|_| true) {
            drained.push(r);
        }
        assert_eq!(drained.len(), 9);
        let f = drained.iter().find(|r| r.id == first).unwrap();
        assert_eq!(f.keep_requested, None);
        assert!(matches!(f.mode, Mode::Griffin { keep, .. }
                         if (keep - 0.75).abs() < 1e-12));
        let d = drained.iter().find(|r| r.id == degraded).unwrap();
        assert_eq!(d.keep_requested, Some(0.75), "audit the client ask");
        assert!(matches!(d.mode, Mode::Griffin { keep, .. }
                         if (keep - 0.5).abs() < 1e-12));
        // queue drained (engine caught up): next admission recovers to
        // Nominal and serves the full keep again
        let (rec, _) = sr.admit(gr(0.75)).unwrap();
        assert_eq!(sr.pressure(), Pressure::Nominal);
        let got = sr.shard(0).router.steal_newest(|_| true).unwrap();
        assert_eq!(got.id, rec);
        assert_eq!(got.keep_requested, None, "no residual degradation");
    }

    #[test]
    fn pressure_hysteresis_holds_between_thresholds() {
        let sr = ShardRouter::new(1, 20, 128);
        for _ in 0..10 {
            sr.admit(req()).unwrap();
        }
        // depth 10/20 = degrade_enter: down-keeping begins
        let (_, _) = sr.admit(gr(0.8)).unwrap();
        assert_eq!(sr.pressure(), Pressure::Degrade);
        let got = sr.shard(0).router.steal_newest(|_| true).unwrap();
        assert_eq!(got.keep_requested, Some(0.8));
        // drain into the hysteresis band (depth 8 → signal 0.4, between
        // degrade_exit 0.35 and degrade_enter 0.5): state must hold
        sr.shard(0).router.steal_newest(|_| true).unwrap();
        sr.shard(0).router.steal_newest(|_| true).unwrap();
        let (_, _) = sr.admit(gr(0.8)).unwrap();
        assert_eq!(sr.pressure(), Pressure::Degrade,
                   "inside the band the dial must not flap");
        let got = sr.shard(0).router.steal_newest(|_| true).unwrap();
        assert_eq!(got.keep_requested, Some(0.8), "still down-kept");
        // drain below degrade_exit (depth 6 → 0.3): recovery
        sr.shard(0).router.steal_newest(|_| true).unwrap();
        sr.shard(0).router.steal_newest(|_| true).unwrap();
        let (_, _) = sr.admit(gr(0.8)).unwrap();
        assert_eq!(sr.pressure(), Pressure::Nominal);
        let got = sr.shard(0).router.steal_newest(|_| true).unwrap();
        assert_eq!(got.keep_requested, None);
    }

    #[test]
    fn downkeep_never_raises_a_low_keep() {
        let sr = ShardRouter::new(1, 4, 128);
        for _ in 0..2 {
            sr.admit(req()).unwrap(); // depth 2/4 → Degrade next
        }
        // a request already at or below the cap is left alone — and
        // carries no degradation provenance
        let (id, _) = sr.admit(gr(0.25)).unwrap();
        assert_eq!(sr.pressure(), Pressure::Degrade);
        let got = sr.shard(0).router.steal_newest(|_| true).unwrap();
        assert_eq!(got.id, id);
        assert_eq!(got.keep_requested, None);
        assert!(matches!(got.mode, Mode::Griffin { keep, .. }
                         if (keep - 0.25).abs() < 1e-12));
    }

    #[test]
    fn slow_shard_sheds_only_its_own_admissions() {
        use crate::metrics::MetricsRegistry;
        use std::time::Duration;
        let sr = ShardRouter::new(2, 64, 128);
        // shard 0 breaches its TTFT SLO badly; shard 1 is healthy and
        // publishes comfortably-in-SLO latencies
        let slow = Arc::new(MetricsRegistry::default());
        for _ in 0..64 {
            slow.ttft.record(Duration::from_secs(60));
        }
        sr.shard(0).publish_metrics(slow);
        let fast = Arc::new(MetricsRegistry::default());
        for _ in 0..64 {
            fast.ttft.record(Duration::from_millis(1));
        }
        sr.shard(1).publish_metrics(fast);
        // sessionless work spills past the shedding shard: the latency
        // breach is shard 0's problem, not the fleet's
        for _ in 0..4 {
            let (_, at) = sr.admit(gr(0.9)).unwrap();
            assert_eq!(at, 1, "slow shard must not take the admission");
        }
        assert_eq!(sr.shard_pressure(0), Pressure::Shed);
        assert_eq!(sr.shard_pressure(1), Pressure::Nominal);
        // the admitted work was NOT down-kept: shard 1 is nominal
        let got = sr.shard(1).router.steal_newest(|_| true).unwrap();
        assert_eq!(got.keep_requested, None);
        // scores spill the same way
        let (_, at) = sr
            .admit_score(ScoreRequest {
                id: 0,
                prompt: vec![1],
                continuation: vec![2],
                mode: Mode::Full,
                admitted_at: std::time::Instant::now(),
            })
            .unwrap();
        assert_eq!(at, 1);
        // a session homed on the slow shard eats the honest refusal —
        // affinity never spills, not even away from a shedding home
        let key = (0..100)
            .map(|i| format!("s{i}"))
            .find(|k| sr.home_shard(k) == 0)
            .unwrap();
        assert!(matches!(
            sr.admit(sreq(&key)),
            Err(AdmitError::Overloaded { .. })
        ));
        // a session homed on the fast shard is untouched
        let key1 = (0..100)
            .map(|i| format!("s{i}"))
            .find(|k| sr.home_shard(k) == 1)
            .unwrap();
        let (_, at) = sr.admit(sreq(&key1)).unwrap();
        assert_eq!(at, 1);
    }

    #[test]
    fn cancel_lands_even_after_a_steal_moves_the_request() {
        let sr = ShardRouter::new(2, 64, 128);
        // shard 1 busy, so both requests land on shard 0
        sr.shard(1).publish_load(4, 4);
        let (_a, at) = sr.admit(req()).unwrap();
        let (b, _) = sr.admit(req()).unwrap();
        assert_eq!(at, 0);
        sr.request_cancel(b);
        // worst-case interleaving: every shard's tick drains the
        // fan-out flags while `b` is still queued, THEN the steal moves
        // it. Pre-fix, the cancel was lost — the thief had already
        // drained its flag and `b` would run to completion.
        assert_eq!(sr.shard(0).router.take_cancelled(), vec![b]);
        assert_eq!(sr.shard(1).router.take_cancelled(), vec![b]);
        sr.shard(1).publish_load(0, 4);
        assert!(sr.rebalance() >= 1, "unflagged newest request steals");
        assert_eq!(sr.shard(1).router.take_cancelled(), vec![b],
                   "the cancel must follow the request to the thief");
    }

    #[test]
    fn cancel_follows_evacuation_from_a_poisoned_shard() {
        let sr = ShardRouter::new(2, 64, 128);
        sr.shard(1).publish_load(4, 4);
        let (id, at) = sr.admit(req()).unwrap();
        assert_eq!(at, 0);
        sr.request_cancel(id);
        // both shards drained their flags before the evacuation
        sr.shard(0).router.take_cancelled();
        sr.shard(1).router.take_cancelled();
        sr.shard(0).poison();
        assert!(sr.rebalance() >= 1, "stranded request evacuates");
        assert_eq!(sr.shard(1).router.take_cancelled(), vec![id],
                   "the cancel must follow the evacuated request");
    }

    #[test]
    fn park_and_revive_lifecycle() {
        let sr = ShardRouter::new(2, 8, 128);
        let s = sr.shard(0);
        assert_eq!((s.restarts(), s.is_parked()), (0, false));
        s.poison();
        assert!(!s.is_healthy() && !s.is_parked());
        // respawn: back in placement, restart counted
        s.revive();
        assert!(s.is_healthy());
        assert_eq!(s.restarts(), 1);
        assert_eq!(sr.place(&req()), Some(0), "revived shard rejoins");
        // circuit breaker: parked implies poisoned and out of placement
        s.park();
        assert!(s.is_parked() && !s.is_healthy());
        assert_eq!(sr.healthy_count(), 1);
        assert_eq!(sr.place(&req()), Some(1));
    }

    #[test]
    fn affinity_fallback_is_deterministic_under_park() {
        let sr = ShardRouter::new(4, 64, 128);
        let home = 1;
        let key = (0..100)
            .map(|i| format!("s{i}"))
            .find(|k| sr.home_shard(k) == home)
            .unwrap();
        sr.shard(home).park();
        // load the ring-wise successor heavier than every other shard:
        // the fallback must STILL pick it — deterministic next-healthy
        // by hash, not least-loaded-per-admit (which would scatter the
        // session across the fleet as the load snapshot drifts)
        let succ = 2;
        sr.shard(succ).publish_load(6, 8);
        for _ in 0..5 {
            let (_, at) = sr.admit(sreq(&key)).unwrap();
            assert_eq!(at, succ, "one successor for the whole session");
        }
        assert_eq!(sr.shard(succ).router.len(), 5);
        // the successor dies too: the ring walks on deterministically
        sr.shard(succ).park();
        assert_eq!(sr.place(&sreq(&key)), Some(3));
        let (_, at) = sr.admit(sreq(&key)).unwrap();
        assert_eq!(at, 3);
        // home revives: affinity snaps straight back
        sr.shard(home).revive();
        let (_, at) = sr.admit(sreq(&key)).unwrap();
        assert_eq!(at, home);
    }

    #[test]
    fn retry_hint_scales_with_refusing_shard_not_fleet() {
        use crate::metrics::MetricsRegistry;
        use std::time::Duration;
        let sr = ShardRouter::new(2, 64, 128);
        // build a backlog of 4 on shard 0 while shard 1 reads busy
        sr.shard(1).publish_load(8, 8);
        for _ in 0..4 {
            let (_, at) = sr.admit(req()).unwrap();
            assert_eq!(at, 0);
        }
        sr.shard(1).publish_load(0, 8);
        // both shards breach the TTFT SLO: every admission sheds
        for s in sr.shards() {
            let m = Arc::new(MetricsRegistry::default());
            for _ in 0..64 {
                m.ttft.record(Duration::from_secs(60));
            }
            s.publish_metrics(m);
        }
        // sessionless work was refused by BOTH shards; the hint backs
        // off for the emptiest refuser (shard 1, depth 0), because
        // that is the first queue a retry could land in — shard 0's
        // backlog of 4 must not inflate it (pre-fix, the fleet-wide
        // depth gave 50 + 20*4 = 130 here)
        match sr.admit(req()).unwrap_err() {
            AdmitError::Overloaded { retry_after_ms } => {
                assert_eq!(retry_after_ms, 50);
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
        // an affine request is refused by its home ALONE, so the hint
        // reflects that one shard's backlog of 4
        let key = (0..100)
            .map(|i| format!("s{i}"))
            .find(|k| sr.home_shard(k) == 0)
            .unwrap();
        match sr.admit(sreq(&key)).unwrap_err() {
            AdmitError::Overloaded { retry_after_ms } => {
                assert_eq!(retry_after_ms, 50 + 20 * 4);
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
    }

    fn preq(tokens: Vec<i32>) -> GenRequest {
        let mut r = GenRequest::greedy(0, tokens, 4, Mode::Full);
        r.id = 0;
        r
    }

    #[test]
    fn prefix_affine_requests_follow_their_cache_shard() {
        let sr = ShardRouter::new(2, 64, 128)
            .with_prefix_block(Some(4));
        let shared: Vec<i32> = vec![1, 2, 3, 4]; // one full block
        let mut turn1 = shared.clone();
        turn1.extend([9, 9, 9]);
        // cold admission places least-loaded (shard 0) and records the
        // opening block in the directory
        let (_, cold) = sr.admit(preq(turn1)).unwrap();
        assert_eq!(cold, 0);
        // shard 0 is now busier, but a prompt sharing the opening
        // block still prefers it — that is where the cached KV lives
        sr.shard(0).publish_load(3, 4);
        let mut turn2 = shared.clone();
        turn2.extend([7, 7, 7, 7, 7]);
        assert_eq!(sr.place(&preq(turn2.clone())), Some(0));
        let (_, at) = sr.admit(preq(turn2)).unwrap();
        assert_eq!(at, 0, "prefix affinity beats least-loaded");
        assert_eq!(sr.shard(0).router.len(), 2,
                   "pinned work stays on its cache shard");
        // a different opening block is not pinned: least-loaded wins
        let (_, other) = sr.admit(preq(vec![5; 6])).unwrap();
        assert_eq!(other, 1);
        // a prompt of exactly one block can never splice a strict
        // prefix, so it is never pinned either
        assert_eq!(sr.place(&preq(shared)), Some(1));
    }

    #[test]
    fn stealing_skips_prefix_pinned_requests() {
        let sr = ShardRouter::new(2, 64, 128)
            .with_prefix_block(Some(4));
        // pin shard 1 busy so both requests queue on shard 0
        sr.shard(1).publish_load(8, 8);
        let (pid, at) = sr.admit(preq(vec![1, 2, 3, 4, 9, 9])).unwrap();
        assert_eq!(at, 0);
        let (uid, at) = sr.admit(preq(vec![8, 8])).unwrap();
        assert_eq!(at, 0);
        // shard 1 goes idle: the steal takes the unpinned request and
        // leaves the prefix-pinned one with its cached KV
        sr.shard(1).publish_load(0, 8);
        assert_eq!(sr.rebalance(), 1);
        let moved = sr.shard(1).router.steal_newest(|_| true).unwrap();
        assert_eq!(moved.id, uid, "short prompt is fair game");
        let stayed = sr.shard(0).router.steal_newest(|_| true).unwrap();
        assert_eq!(stayed.id, pid, "pinned request stays put");
    }

    #[test]
    fn single_shard_degenerates_to_plain_router() {
        let sr = ShardRouter::new(1, 4, 128).with_slo(no_shed());
        for _ in 0..4 {
            let (_, at) = sr.admit(req()).unwrap();
            assert_eq!(at, 0);
        }
        assert!(matches!(
            sr.admit(req()),
            Err(AdmitError::QueueFull { capacity: 4 })
        ));
        assert_eq!(sr.rebalance(), 0, "nothing to steal from yourself");
    }
}
