//! The serving engine: ties runtime + selection + sampling into the
//! prompt-phase / generation-phase flow of the paper (Fig. 3).
//!
//!   prompt  →  prefill executable (full model, emits s per FF block)
//!   select  →  host-side strategy over s (GRIFFIN §4.2 / baselines)
//!   gather  →  gather_k executable builds Ŵ_g, Ŵ_1, Ŵ_2 on device
//!   generate→  decode_pruned steps (or full decode / masked-weight decode
//!              for the baselines), KV-cache device-resident throughout.
//!
//! Decode runs through prepared [`DispatchPlan`]s (runtime/): the
//! ~full-parameter argument vector is bound once per (executable,
//! weight-set) and per-step calls supply only the dynamic tail. The
//! fused generation path (`decode_sample_step`) additionally samples
//! ON DEVICE — greedy / seeded top-k via the compiled sampler ABI
//! (model.sample_tokens ↔ sampling::DeviceSampler) — so the `[B, vocab]`
//! logits tensor never crosses the host boundary during steady-state
//! generation; only token ids and logprobs (O(B) bytes/step) come back.
//! Pruned weight sets are reused through an LRU keyed by the expert
//! selection (`gather_cached`), so unchanged selections skip
//! `gather_k{K}` entirely.
//!
//! Admission is device-resident too when the artifacts provide the
//! admission ABI: [`Engine::prefill_sample`] reduces the prompt phase to
//! last-token logits, samples the first token on device, and downloads
//! only the selection statistics the mode actually consumes
//! ([`StatNeeds`]); [`Engine::splice_slots`] routes through a compiled
//! `splice_b{src}_b{dst}` executable that dynamic-update-slices the
//! prefilled KV rows into the persistent decode state's slot positions
//! — no `[B, S, V]` logits download and no host-side KV round trip per
//! accepted request. Both fall back to the host paths for artifact sets
//! that predate the admission ABI. Routing is BY NEED: callers that
//! score prompt positions ([`PrefillLogits::Full`]) are structurally
//! kept on the full-logits `prefill`. See docs/architecture.md for the
//! host-boundary budget.
//!
//! Everything here is single-threaded by design: `PjRtBuffer` is not
//! `Send`, so the engine owns all device state and the server hands it
//! work through channels (server/).

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
#[cfg(feature = "runtime")]
use std::path::Path;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::{ExecutableSpec, ModelConfig};
use crate::coordinator::gather_cache::{GatherCache, GatherKey};
use crate::coordinator::selection::{self, LayerStats, Strategy};
use crate::coordinator::sequence::{FinishReason, GenRequest};
use crate::metrics::{MetricsRegistry, Timer};
use crate::runtime::{DeviceTensor, DispatchPlan, Substrate, WeightStore};
use crate::sampling::{
    device_params, log_softmax_at, seed_state, Sampler, SamplerSpec,
};
use crate::tensorfile::TensorMap;
use crate::tokenizer::{Tokenizer, EOS_ID, PAD_ID};

/// Device-resident pruned weight sets kept for reuse (gather_cached).
const GATHER_CACHE_CAP: usize = 8;

/// Non-base-weight dispatch plans kept alive (each pins one pruned /
/// override weight set via `Rc`). A weight set can own up to TWO plans
/// (the fused decode_*_sample variant and the host decode_* variant),
/// so the cap is twice the gather cache: a pool cycling through every
/// cached selection on both routing paths never thrashes plan rebuilds.
const PLAN_CACHE_CAP: usize = 2 * GATHER_CACHE_CAP;

/// Masked (layer-adaptive) gather artifacts are emitted only at the
/// paper's headline 50% operating point (aot.py `emit_gather_masked` at
/// k_half), so the layer-adaptive path always gathers at this bucket and
/// realizes smaller per-layer budgets through the validity mask.
pub const ADAPTIVE_HEADLINE_KEEP: f64 = 0.5;

/// Keep fraction whose compiled bucket hosts a layer-adaptive gather:
/// constant (the headline bucket), independent of the requested average
/// keep — that only shapes the per-layer budget allocation. Replaces a
/// former `keep.min(0.5).max(0.5)` no-op clamp that obscured this.
pub fn adaptive_bucket_keep(_requested_keep: f64) -> f64 {
    ADAPTIVE_HEADLINE_KEEP
}

// Runtime-free coordinator types (Mode, GenResponse) live in
// `coordinator::types` so the substrate layers build without PJRT; they
// are re-exported here under their historical paths.
pub use crate::coordinator::types::{CacheInfo, GenResponse, Mode,
                                    SelectionInfo, SpecInfo};

/// Device-resident pruned FF weights for one expert set. Shared handles
/// (`Rc`) so the same set can live in the gather cache, a dispatch
/// plan's static prefix, and the scheduler's batch-shared state at once.
pub struct PrunedWeights {
    /// in manifest pruned_param_order (w1p, w2p[, wgp])
    pub tensors: Vec<Rc<DeviceTensor>>,
    /// uniform FF width, or — for ragged sets — the FLOP-matched
    /// average width (Σ layer_ks / L), which is what `k_used` reports
    pub k: usize,
    /// per-layer FF widths of a ragged (adaptive-layer) set; None for
    /// uniform sets. Decides the decode executable family:
    /// `decode_pruned*_b{B}_k{K}` vs `decode_pruned*_b{B}_l{k0}x{k1}`.
    pub layer_ks: Option<Vec<usize>>,
    /// unique weight-set id — keys the prepared-dispatch-plan cache
    id: u64,
}

/// Name fragment of a ragged per-layer-k profile (`8x24`), matching
/// aot.py `lname` / runtime::cpu `ragged_name`.
pub fn profile_frag(lks: &[usize]) -> String {
    lks.iter()
        .map(|k| k.to_string())
        .collect::<Vec<_>>()
        .join("x")
}

/// Snap an allocator target to the nearest compiled per-layer profile:
/// smallest L1 distance first, ties broken toward the larger dot
/// product with the target (prefer the candidate tilting the same way),
/// remaining ties lexicographic. None only for an empty candidate set.
pub fn snap_profile(cands: &[Vec<usize>], target: &[usize])
                    -> Option<Vec<usize>> {
    let mut sorted: Vec<&Vec<usize>> = cands
        .iter()
        .filter(|c| c.len() == target.len())
        .collect();
    sorted.sort();
    sorted
        .into_iter()
        .min_by_key(|c| {
            let l1: i64 = c
                .iter()
                .zip(target)
                .map(|(&a, &b)| (a as i64 - b as i64).abs())
                .sum();
            let dot: i64 = c
                .iter()
                .zip(target)
                .map(|(&a, &b)| (a * b) as i64)
                .sum();
            (l1, -dot)
        })
        .cloned()
}

/// Full-size replacement FF stacks (the Wanda baseline): w1, w2 [, wg]
/// uploaded as masked copies. Carries a weight-set id for the same
/// plan-cache reasons as [`PrunedWeights`].
pub struct FfOverride {
    pub tensors: Vec<Rc<DeviceTensor>>,
    id: u64,
}

/// Device-resident per-slot sampling state for the fused decode path:
/// per-slot temperature/top-k parameters and the xorshift32 RNG stream
/// (see the fused-sampling ABI in python/compile/model.py). `tokens`
/// holds the previous step's sampled ids so steady-state ticks chain
/// decode input on device without a host upload.
pub struct SamplingState {
    pub temp: DeviceTensor,
    pub topk: DeviceTensor,
    pub rng: DeviceTensor,
    pub tokens: Option<DeviceTensor>,
    pub batch: usize,
}

/// Device-resident per-batch decode state.
pub struct DecodeState {
    pub kcache: DeviceTensor,
    pub vcache: DeviceTensor,
    /// per-slot next write position (== tokens seen so far)
    pub pos: Vec<i32>,
    /// Device-chained copy of `pos` on the fused decode path: the
    /// `decode_*_sample` executables output the advanced position
    /// (input pos + 1), so steady-state fused ticks upload no pos
    /// vector at all. `None` means stale — the next fused step seeds
    /// the chain by uploading the host mirror once. Any host-side
    /// write to `pos` outside the fused step (splice, retirement,
    /// host-path decode) must call [`DecodeState::invalidate_pos`].
    pos_dev: Option<DeviceTensor>,
    pub batch: usize,
}

impl DecodeState {
    /// Drop the device-chained pos copy after a host-side `pos` write
    /// (slot-membership change / host-path step); the next fused step
    /// re-uploads the host mirror once.
    pub fn invalidate_pos(&mut self) {
        self.pos_dev = None;
    }

    /// Whether the fused decode path currently chains pos on device
    /// (no per-step upload). Test/bench introspection.
    pub fn pos_resident(&self) -> bool {
        self.pos_dev.is_some()
    }
}

/// Device-resident state of an in-flight chunked positioned prefill
/// (`prefill_sample_b1_s{S}_p`): the growing single-sequence KV pair
/// plus the RUNNING PRE-SQRT selection-statistic sums threaded chunk to
/// chunk (sqrt is applied once at the end — [`Engine::chunk_stats`] —
/// so the chunked statistics are bit-identical to the single-shot
/// prefill's). Tensors are `Rc`-shared so a block-aligned snapshot can
/// be retained by the prefix cache while later chunks continue from it:
/// the substrate is purely functional (inputs are never mutated), so
/// sharing is safe, and `Clone` is cheap handle duplication.
#[derive(Clone)]
pub struct ChunkState {
    pub kcache: Rc<DeviceTensor>,
    pub vcache: Rc<DeviceTensor>,
    /// running Σ zbar² (pre-sqrt GRIFFIN eq.6 sums) [L, 1, d_ff]
    pub stats: Rc<DeviceTensor>,
    /// running Σ x² (pre-sqrt Wanda input norms) [L, 1, d_model]
    pub xnorms: Rc<DeviceTensor>,
    /// running Σ z² (pre-sqrt Wanda activation norms) [L, 1, d_ff]
    pub znorms: Rc<DeviceTensor>,
    /// prompt rows resident in the caches — the absolute start position
    /// of the next chunk (block-aligned between chunks)
    pub filled: usize,
}

impl ChunkState {
    /// Device bytes this state's tensors occupy (f32) — what a prefix-
    /// cache entry charges against its byte budget. Shared `Rc` handles
    /// (the zero templates, snapshots) are charged at full size per
    /// holder: the budget bounds worst-case residency, not the
    /// deduplicated optimum.
    pub fn payload_bytes(&self) -> u64 {
        [&self.kcache, &self.vcache, &self.stats, &self.xnorms,
         &self.znorms]
            .iter()
            .map(|t| t.element_count() as u64 * 4)
            .sum()
    }
}

/// What the caller needs back from the prompt phase. Admission routing
/// is BY NEED: the reduced `prefill_sample_*` executables cannot serve
/// per-position prompt logits, so callers that score the prompt
/// (`Full`) stay on the full-logits `prefill` structurally — they can
/// never be silently routed onto the reduced variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefillLogits {
    /// each sequence's last-token logits row only (generation paths)
    LastToken,
    /// the full [B, S, V] prompt logits (per-position NLLs /
    /// score_prompt; `PrefillOut::prompt_logits` is populated)
    Full,
}

/// Which host-side statistics an admission needs downloaded — also
/// route-by-need: Full/Magnitude admissions consume none of them,
/// GRIFFIN needs the eq.6 stats, Wanda the input/activation norms.
#[derive(Debug, Clone, Copy)]
pub struct StatNeeds {
    pub stats: bool,
    pub norms: bool,
}

impl StatNeeds {
    pub fn for_mode(mode: &Mode) -> StatNeeds {
        match mode {
            Mode::Griffin { .. } => StatNeeds { stats: true, norms: false },
            Mode::Wanda { .. } => StatNeeds { stats: false, norms: true },
            Mode::Full | Mode::Magnitude { .. } => {
                StatNeeds { stats: false, norms: false }
            }
        }
    }

    pub fn all() -> StatNeeds {
        StatNeeds { stats: true, norms: true }
    }
}

/// Host-side results of the prompt phase.
pub struct PrefillOut {
    pub state: DecodeState,
    /// per-sequence, per-layer GRIFFIN statistic s
    pub stats: Vec<LayerStats>,
    /// per-sequence, per-layer FF input column norms (Wanda W1/Wg scores)
    pub xnorms: Vec<LayerStats>,
    /// per-sequence, per-layer raw-activation column norms (Wanda W2)
    pub znorms: Vec<LayerStats>,
    /// logits at each sequence's last real prompt token
    pub last_logits: Vec<Vec<f32>>,
    /// full prompt logits [B][S][V] (kept only for PrefillLogits::Full)
    pub prompt_logits: Option<Vec<f32>>,
    pub bucket_seq: usize,
    pub lengths: Vec<usize>,
}

/// Host-side results of the device-resident admission prompt phase
/// (`prefill_sample_*`): the first token is already sampled on device,
/// and only the statistics the admission's mode needs were downloaded.
pub struct FusedPrefillOut {
    pub state: DecodeState,
    pub stats: Option<Vec<LayerStats>>,
    pub xnorms: Option<Vec<LayerStats>>,
    pub znorms: Option<Vec<LayerStats>>,
    /// device-sampled first token per real sequence
    pub tokens: Vec<i32>,
    /// log-probability of each sampled first token
    pub logprobs: Vec<f32>,
    pub bucket_seq: usize,
    pub lengths: Vec<usize>,
}

/// A prompt batch packed to its compiled (batch, seq) bucket.
struct PackedPrompts {
    batch: usize,
    bucket_seq: usize,
    exe: String,
    tokens: Vec<i32>,
    lengths: Vec<usize>,
    lens_i32: Vec<i32>,
}

pub struct Engine {
    /// The executable substrate this engine dispatches to — the PJRT
    /// backend (`Engine::load`) or the CPU reference backend
    /// (`Engine::cpu_reference`). Everything below this field is
    /// backend-agnostic.
    pub session: Box<dyn Substrate>,
    pub weights: WeightStore,
    /// host copy (magnitude / wanda baselines need raw weight values)
    pub host_weights: TensorMap,
    pub tokenizer: Tokenizer,
    /// shared with the session (host-transfer counters land there too)
    pub metrics: Arc<MetricsRegistry>,
    /// prepared dispatch plans keyed by (executable, weight-set id);
    /// value carries an LRU tick. Non-base entries are capped at
    /// PLAN_CACHE_CAP because each pins a weight set via Rc.
    plans: RefCell<BTreeMap<(String, u64), (u64, Rc<DispatchPlan>)>>,
    plan_ticks: Cell<u64>,
    /// pruned-weight reuse, keyed by (k, expert-index hash)
    gather_cache: GatherCache<Rc<PrunedWeights>>,
    /// monotonically increasing weight-set ids (0 = base WeightStore)
    set_ids: Cell<u64>,
    magnitude_cache: Option<Vec<Vec<i32>>>, // per keep-k gather idx cache
    magnitude_keep: f64,
    /// shared zero seed of every cold chunked prefill: the substrate
    /// never mutates inputs, so one uploaded zero-state serves all cold
    /// admissions (no per-admission Smax-proportional zero upload)
    chunk_zero: RefCell<Option<ChunkState>>,
}

impl Engine {
    /// Load over the PJRT backend (compiled artifacts + weights.bin).
    #[cfg(feature = "runtime")]
    pub fn load(artifact_dir: &Path, trained: bool) -> Result<Engine> {
        let session = crate::runtime::Session::load(artifact_dir)?;
        Engine::from_substrate(Box::new(session), trained)
    }

    /// Load over the CPU reference backend: a tiny synthesized model
    /// served by the pure-Rust interpreter (runtime/cpu.rs) — the full
    /// engine/scheduler/server stack with no PJRT library and no
    /// `make artifacts` step (the hard-gated CI e2e tier).
    #[cfg(feature = "cpu-substrate")]
    pub fn cpu_reference() -> Result<Engine> {
        let session = crate::runtime::cpu::CpuSession::new();
        Engine::from_substrate(Box::new(session), false)
    }

    /// Build an engine over any [`Substrate`]. The host weight copy
    /// (magnitude / wanda baselines need raw values) is loaded once and
    /// uploaded through the trait, so both backends share one path.
    pub fn from_substrate(session: Box<dyn Substrate>, trained: bool)
                          -> Result<Engine> {
        let host_weights = session.load_host_weights(trained)?;
        let weights = WeightStore::from_host(&*session, &host_weights)?;
        let metrics = session.metrics().clone();
        Ok(Engine {
            session,
            weights,
            host_weights,
            tokenizer: Tokenizer::new(),
            metrics,
            plans: RefCell::new(BTreeMap::new()),
            plan_ticks: Cell::new(0),
            gather_cache: GatherCache::new(GATHER_CACHE_CAP),
            set_ids: Cell::new(1),
            magnitude_cache: None,
            magnitude_keep: -1.0,
            chunk_zero: RefCell::new(None),
        })
    }

    fn next_set_id(&self) -> u64 {
        let id = self.set_ids.get();
        self.set_ids.set(id + 1);
        id
    }

    pub fn config(&self) -> &ModelConfig {
        &self.session.manifest().config
    }

    // ------------------------------------------------------------------
    // prompt phase
    // ------------------------------------------------------------------

    /// Pack a prompt batch to its compiled (batch, seq) bucket of the
    /// given executable kind ("prefill" / "prefill_sample"): pad the
    /// token matrix with dummy rows, resolve the smallest fitting seq
    /// bucket. A prompt longer than every compiled bucket is an ERROR:
    /// the old behavior silently clamped to the largest bucket
    /// (tokenizer::fit keeps the suffix), which truncated the prompt's
    /// prefix without any signal to the caller. Admission now rejects
    /// such prompts up front (`Router` max_prompt) or serves them
    /// through the chunked positioned prefill ([`Engine::prefill_chunk`])
    /// when the artifacts provide it — never a silent snap.
    fn pack_prompts(&self, prompts: &[Vec<i32>], kind: &str)
                    -> Result<PackedPrompts> {
        let n = prompts.len();
        let batch = self
            .session
            .manifest()
            .batch_bucket(n)
            .with_context(|| format!("no batch bucket >= {n}"))?;
        let longest = prompts.iter().map(Vec::len).max().unwrap_or(1).max(1);
        let exe = match self.session.manifest().seq_bucket(kind, batch,
                                                           longest) {
            Some(e) => e.name.clone(),
            None => {
                let largest = self
                    .session
                    .manifest()
                    .largest_seq_bucket(kind, batch)
                    .and_then(|e| e.seq);
                match largest {
                    Some(s) => bail!(
                        "prompt of {longest} tokens exceeds the largest \
                         compiled {kind} seq bucket ({s}) at batch={batch}; \
                         over-long prompts must be rejected at admission \
                         or chunk-prefilled, never truncated"),
                    None => bail!("no {kind} executable for batch={batch}"),
                }
            }
        };
        let bucket_seq = self.session.manifest().executables[&exe]
            .seq
            .unwrap();

        // pad the token matrix: real sequences then dummy rows
        let mut tokens = Vec::with_capacity(batch * bucket_seq);
        let mut lengths = Vec::with_capacity(batch);
        for i in 0..batch {
            let ids: &[i32] = if i < n { &prompts[i] } else { &[] };
            let (row, real) = self.tokenizer.fit(ids, bucket_seq);
            // empty dummy rows still need length >= 1 for valid attention
            lengths.push(real.max(1));
            tokens.extend(if real == 0 {
                vec![PAD_ID; bucket_seq]
            } else {
                row
            });
        }
        let lens_i32 = lengths.iter().map(|&l| l as i32).collect();
        Ok(PackedPrompts { batch, bucket_seq, exe, tokens, lengths,
                           lens_i32 })
    }

    /// Split a downloaded [L, B, width] statistics tensor into per-
    /// sequence [L][width] stacks for the first `n` rows.
    fn split_layer_stats(&self, t: &DeviceTensor, width: usize, n: usize,
                         batch: usize) -> Result<Vec<LayerStats>> {
        let host = self.session.download_f32(t)?;
        let l_count = self.config().n_layers;
        Ok((0..n)
            .map(|i| {
                (0..l_count)
                    .map(|l| {
                        let base = (l * batch + i) * width;
                        host[base..base + width].to_vec()
                    })
                    .collect()
            })
            .collect())
    }

    /// Run the prompt phase for a batch of prompts (padded to buckets).
    /// This is the FULL-LOGITS family: the whole [B, S, V] logits tensor
    /// is downloaded, and `need` controls whether the per-position rows
    /// are retained (`PrefillLogits::Full`) or only each sequence's
    /// last-token row survives. Admission paths that need neither use
    /// [`Engine::prefill_sample`] instead.
    pub fn prefill(&self, prompts: &[Vec<i32>], need: PrefillLogits)
                   -> Result<PrefillOut> {
        let t = Timer::start();
        let cfg = self.config();
        let n = prompts.len();
        let p = self.pack_prompts(prompts, "prefill")?;
        let toks_dev = self
            .session
            .upload_i32(&[p.batch, p.bucket_seq], &p.tokens)?;
        let lens_dev = self.session.upload_i32(&[p.batch], &p.lens_i32)?;

        let mut args: Vec<&DeviceTensor> = self.weights.ordered();
        args.push(&toks_dev);
        args.push(&lens_dev);
        let mut outs = self.session.run(&p.exe, &args)?;
        // outputs: logits, kcache, vcache, stats, xnorms, znorms
        let znorms_t = outs.pop().unwrap();
        let xnorms_t = outs.pop().unwrap();
        let stats_t = outs.pop().unwrap();
        let vcache = outs.pop().unwrap();
        let kcache = outs.pop().unwrap();
        let logits_t = outs.pop().unwrap();

        let v = cfg.vocab_size;
        let logits = self.session.download_f32(&logits_t)?;
        let last_logits: Vec<Vec<f32>> = (0..n)
            .map(|i| {
                let row = (i * p.bucket_seq + (p.lengths[i] - 1)) * v;
                logits[row..row + v].to_vec()
            })
            .collect();

        let stats = self.split_layer_stats(&stats_t, cfg.d_ff, n, p.batch)?;
        let xnorms =
            self.split_layer_stats(&xnorms_t, cfg.d_model, n, p.batch)?;
        let znorms = self.split_layer_stats(&znorms_t, cfg.d_ff, n, p.batch)?;

        self.metrics.prompt_tokens.add(
            p.lengths.iter().take(n).sum::<usize>() as u64);
        t.record_into(&self.metrics.prefill_latency);

        Ok(PrefillOut {
            state: DecodeState {
                kcache,
                vcache,
                pos: p.lens_i32,
                pos_dev: None,
                batch: p.batch,
            },
            stats,
            xnorms,
            znorms,
            last_logits,
            prompt_logits: if need == PrefillLogits::Full {
                Some(logits)
            } else {
                None
            },
            bucket_seq: p.bucket_seq,
            lengths: p.lengths,
        })
    }

    /// The compiled sampler truncation cap of the reduced admission
    /// prefill for a prompt set of this size — the MINIMUM over the
    /// batch bucket's seq buckets, since eligibility must hold
    /// whichever bucket `pack_prompts` resolves. `sample_topk` is
    /// per-executable in the manifest, so this can differ from the
    /// decode executables' cap; admission eligibility must check THIS
    /// cap, not the decode one. None = no admission ABI (old artifact
    /// sets — callers fall back to [`Engine::prefill`]).
    pub fn fused_prefill_cap(&self, n_prompts: usize) -> Option<usize> {
        let batch = self.session.manifest().batch_bucket(n_prompts)?;
        self.session
            .manifest()
            .executables
            .values()
            .filter(|e| {
                e.kind == "prefill_sample" && e.batch == Some(batch)
            })
            .map(|e| {
                e.sample_topk.unwrap_or(crate::sampling::SAMPLE_TOPK)
            })
            .min()
    }

    /// Does the manifest provide the reduced admission prefill for a
    /// prompt set of this size?
    pub fn can_prefill_fused(&self, n_prompts: usize) -> bool {
        self.fused_prefill_cap(n_prompts).is_some()
    }

    /// Device-resident admission prompt phase (`prefill_sample_*`): the
    /// [B, S, V] prompt logits are never materialized — only the
    /// last-token hidden rows go through the LM head, the first token of
    /// each sequence is sampled ON DEVICE through the fused-sampling ABI
    /// (`samplers`: one (spec, xorshift32 state) pair per real prompt,
    /// pad lanes get greedy placeholders), and only the statistics in
    /// `needs` are downloaded. The device RNG output is discarded: the
    /// slots' host mirrors are the stream source of truth and advance in
    /// lockstep (`DeviceSampler::skip`, one advance per executable call).
    ///
    /// Callers needing per-position prompt logits must use `prefill`
    /// with [`PrefillLogits::Full`] — this variant cannot serve them.
    pub fn prefill_sample(&self, prompts: &[Vec<i32>],
                          samplers: &[(SamplerSpec, u32)], needs: StatNeeds)
                          -> Result<FusedPrefillOut> {
        let t = Timer::start();
        let cfg = self.config();
        let n = prompts.len();
        if samplers.len() != n {
            bail!("prefill_sample: {} sampler lanes for {n} prompts",
                  samplers.len());
        }
        let p = self.pack_prompts(prompts, "prefill_sample")?;
        let toks_dev = self
            .session
            .upload_i32(&[p.batch, p.bucket_seq], &p.tokens)?;
        let lens_dev = self.session.upload_i32(&[p.batch], &p.lens_i32)?;

        // sampling lanes: real sequences, then greedy pad lanes
        let mut temp = vec![0f32; p.batch];
        let mut topk = vec![1i32; p.batch];
        let mut rng = vec![seed_state(0) as i32; p.batch];
        for (i, (spec, state)) in samplers.iter().enumerate() {
            let (tv, kv) = device_params(*spec);
            temp[i] = tv;
            topk[i] = kv;
            rng[i] = *state as i32;
        }
        let temp_dev = self.session.upload_f32(&[p.batch], &temp)?;
        let topk_dev = self.session.upload_i32(&[p.batch], &topk)?;
        let rng_dev = self.session.upload_i32(&[p.batch], &rng)?;

        let mut args: Vec<&DeviceTensor> = self.weights.ordered();
        args.push(&toks_dev);
        args.push(&lens_dev);
        args.push(&temp_dev);
        args.push(&topk_dev);
        args.push(&rng_dev);
        let mut outs = self.session.run(&p.exe, &args)?;
        // outputs: token, logprob, kcache, vcache, stats, xnorms,
        // znorms, rng
        let _rng_out = outs.pop().unwrap();
        let znorms_t = outs.pop().unwrap();
        let xnorms_t = outs.pop().unwrap();
        let stats_t = outs.pop().unwrap();
        let vcache = outs.pop().unwrap();
        let kcache = outs.pop().unwrap();
        let lp_t = outs.pop().unwrap();
        let tok_t = outs.pop().unwrap();

        let mut tokens = self.session.download_i32(&tok_t)?;
        tokens.truncate(n);
        let mut logprobs = self.session.download_f32(&lp_t)?;
        logprobs.truncate(n);
        let stats = if needs.stats {
            Some(self.split_layer_stats(&stats_t, cfg.d_ff, n, p.batch)?)
        } else {
            None
        };
        let (xnorms, znorms) = if needs.norms {
            (
                Some(self.split_layer_stats(
                    &xnorms_t, cfg.d_model, n, p.batch)?),
                Some(self.split_layer_stats(
                    &znorms_t, cfg.d_ff, n, p.batch)?),
            )
        } else {
            (None, None)
        };

        self.metrics.prompt_tokens.add(
            p.lengths.iter().take(n).sum::<usize>() as u64);
        self.metrics.fused_admissions.inc();
        t.record_into(&self.metrics.prefill_latency);

        Ok(FusedPrefillOut {
            state: DecodeState {
                kcache,
                vcache,
                pos: p.lens_i32,
                pos_dev: None,
                batch: p.batch,
            },
            stats,
            xnorms,
            znorms,
            tokens,
            logprobs,
            bucket_seq: p.bucket_seq,
            lengths: p.lengths,
        })
    }

    // ------------------------------------------------------------------
    // expert selection + gather
    // ------------------------------------------------------------------

    /// Round a keep fraction to the nearest compiled k bucket.
    pub fn k_for(&self, keep: f64) -> Result<usize> {
        self.session
            .manifest()
            .nearest_k(keep)
            .context("config has no keep_ks")
    }

    /// The keep fraction actually servable at `batch`: the continuous
    /// scheduler always decodes at the pool's compiled bucket, and
    /// aot.py emits the full k sweep only at B=1 (larger buckets get
    /// the headline k). Requests whose keep has no decode_pruned
    /// executable at this bucket are snapped to the nearest one instead
    /// of failing deep in the decode loop with "unknown executable".
    /// Batching compatibility at a given pool batch size: like
    /// [`Mode::compatible`], but Griffin/Magnitude keeps that snap to
    /// the same compiled decode bucket (`bucket_keep`) batch together —
    /// e.g. griffin@0.75 and griffin@0.5 are served identically at a
    /// bucket that only compiles k_half, so splitting them into
    /// separate waves would waste the batch for nothing.
    pub fn modes_batchable(&self, batch: usize, a: &Mode, b: &Mode)
                           -> bool {
        if a.compatible(b) {
            return true;
        }
        let snap = |m: &Mode| -> Option<Mode> {
            match *m {
                Mode::Griffin { keep, strategy } => self
                    .bucket_keep(batch, keep)
                    .ok()
                    .map(|k| Mode::Griffin { keep: k, strategy }),
                Mode::Magnitude { keep } => self
                    .bucket_keep(batch, keep)
                    .ok()
                    .map(|k| Mode::Magnitude { keep: k }),
                // Full has no keep; Wanda masks a continuous fraction
                // that is not bucketed — no snapping for either
                _ => None,
            }
        };
        match (snap(a), snap(b)) {
            (Some(x), Some(y)) => x.compatible(&y),
            _ => false,
        }
    }

    pub fn bucket_keep(&self, batch: usize, keep: f64) -> Result<f64> {
        self.snap_keep("decode_pruned", batch, keep)
    }

    /// Snap `keep` to the nearest k compiled for `kind` at `batch`
    /// (shared by the decode and fused-scan paths — aot.py emits
    /// different k coverage per executable kind). Out-of-range keeps are
    /// engine errors: the API layer rejects them at admission, and this
    /// guard keeps a request injected past admission (internal callers,
    /// tests) from being silently snapped to a bucket it never asked for.
    fn snap_keep(&self, kind: &str, batch: usize, keep: f64)
                 -> Result<f64> {
        if keep.is_nan() || keep <= 0.0 || keep > 1.0 {
            bail!("keep {keep} outside (0,1]");
        }
        let cfg = self.config();
        let mut candidates: Vec<usize> = self
            .session
            .manifest()
            .executables
            .values()
            .filter(|e| e.kind == kind && e.batch == Some(batch))
            .filter_map(|e| e.k)
            .collect();
        // ascending k, so an exact midpoint between two compiled
        // buckets snaps to the SMALLER k everywhere (`nearest_k_of`
        // keeps the first of tied candidates) — executable-name
        // iteration order put k16 before k8 and made tie resolution an
        // accident of naming
        candidates.sort_unstable();
        crate::config::nearest_k_of(cfg.d_ff as f64 * keep, candidates)
            .map(|k| k as f64 / cfg.d_ff as f64)
            .with_context(|| {
                format!("no {kind} executables for batch={batch}")
            })
    }

    /// Build device-resident pruned FF weights for an expert index set.
    pub fn gather(&self, idx: &[Vec<i32>]) -> Result<PrunedWeights> {
        let t = Timer::start();
        let cfg = self.config();
        let k = idx[0].len();
        if idx.len() != cfg.n_layers || idx.iter().any(|l| l.len() != k) {
            bail!("gather: idx must be [L][k]");
        }
        let name = format!("gather_k{k}");
        if !self.session.manifest().executables.contains_key(&name) {
            bail!("no gather executable for k={k} \
                   (available: {:?})", cfg.keep_ks);
        }
        let flat: Vec<i32> = idx.iter().flatten().copied().collect();
        let idx_dev = self.session.upload_i32(&[cfg.n_layers, k], &flat)?;
        // ff params in the order aot emitted them: w1, w2 [, wg]
        let mut args: Vec<&DeviceTensor> = vec![
            self.weights.get("w1"),
            self.weights.get("w2"),
        ];
        if cfg.is_glu {
            args.push(self.weights.get("wg"));
        }
        args.push(&idx_dev);
        let outs = self.session.run(&name, &args)?;
        t.record_into(&self.metrics.gather_latency);
        Ok(self.make_pruned(outs, k))
    }

    /// Wrap raw gather outputs as a [`PrunedWeights`] set with a fresh
    /// weight-set id (also used by experiment drivers running custom
    /// gather executables).
    pub fn make_pruned(&self, tensors: Vec<DeviceTensor>, k: usize)
                       -> PrunedWeights {
        PrunedWeights {
            tensors: tensors.into_iter().map(Rc::new).collect(),
            k,
            layer_ks: None,
            id: self.next_set_id(),
        }
    }

    /// Ragged (adaptive-layer) gather: build device-resident pruned FF
    /// weights at per-layer widths through the compiled
    /// `gather_l{k0}x{k1}` executable for this exact profile. The index
    /// set is flat-packed `[Σk]` in layer order, matching the ragged
    /// gather ABI (python/compile/model.py `gather_experts_ragged`).
    pub fn gather_ragged(&self, idx: &[Vec<i32>]) -> Result<PrunedWeights> {
        let t = Timer::start();
        let cfg = self.config();
        if idx.len() != cfg.n_layers {
            bail!("gather_ragged: idx must have one row per layer");
        }
        let lks: Vec<usize> = idx.iter().map(Vec::len).collect();
        let name = format!("gather_l{}", profile_frag(&lks));
        if !self.session.manifest().executables.contains_key(&name) {
            bail!("no {name} executable for profile {lks:?} \
                   (re-run make artifacts)");
        }
        let flat: Vec<i32> = idx.iter().flatten().copied().collect();
        let idx_dev = self.session.upload_i32(&[flat.len()], &flat)?;
        let mut args: Vec<&DeviceTensor> = vec![
            self.weights.get("w1"),
            self.weights.get("w2"),
        ];
        if cfg.is_glu {
            args.push(self.weights.get("wg"));
        }
        args.push(&idx_dev);
        let outs = self.session.run(&name, &args)?;
        t.record_into(&self.metrics.gather_latency);
        let k_avg = lks.iter().sum::<usize>() / lks.len().max(1);
        Ok(PrunedWeights {
            tensors: outs.into_iter().map(Rc::new).collect(),
            k: k_avg,
            layer_ks: Some(lks),
            id: self.next_set_id(),
        })
    }

    /// [`Engine::gather_ragged`] through the pruned-weight reuse cache.
    /// Ragged and uniform selections share the cache safely: the key
    /// hashes per-layer boundaries and a hit requires exact index-set
    /// equality, so a ragged set can never alias a uniform one.
    pub fn gather_ragged_cached(&mut self, idx: &[Vec<i32>])
                                -> Result<Rc<PrunedWeights>> {
        let key = GatherKey::new(idx);
        if let Some(pw) = self.gather_cache.get(&key, idx) {
            self.metrics.gather_cache_hits.inc();
            return Ok(pw.clone());
        }
        self.metrics.gather_cache_misses.inc();
        let pw = Rc::new(self.gather_ragged(idx)?);
        self.gather_cache.insert(key, idx.to_vec(), pw.clone());
        Ok(pw)
    }

    /// `gather` through the pruned-weight reuse cache: an expert index
    /// set that is already resident on device (keyed by (k, index hash))
    /// is returned without running `gather_k{K}`. Hit/miss counts land
    /// in `metrics.gather_cache_{hits,misses}` — the scheduler leans on
    /// this so slot back-fill with an unchanged selection (magnitude
    /// mode, stable eq.7 aggregates, re-admitted single-slot prompts)
    /// costs zero gather executions.
    pub fn gather_cached(&mut self, idx: &[Vec<i32>])
                         -> Result<Rc<PrunedWeights>> {
        let key = GatherKey::new(idx);
        if let Some(pw) = self.gather_cache.get(&key, idx) {
            self.metrics.gather_cache_hits.inc();
            return Ok(pw.clone());
        }
        self.metrics.gather_cache_misses.inc();
        let pw = Rc::new(self.gather(idx)?);
        self.gather_cache.insert(key, idx.to_vec(), pw.clone());
        Ok(pw)
    }

    /// Layer-adaptive gather (extension; DESIGN.md §6): per-layer budgets
    /// under a global average keep fraction, padded slots masked to zero.
    pub fn gather_adaptive(&self, stats: &LayerStats, keep: f64)
                           -> Result<PrunedWeights> {
        let t = Timer::start();
        let cfg = self.config();
        let k_bucket = self.k_for(adaptive_bucket_keep(keep))?;
        let k_avg = ((cfg.d_ff as f64 * keep).round() as usize)
            .min(k_bucket);
        let (idx, mask) = selection::adaptive_layer_allocation(
            stats, k_avg, k_bucket);
        let name = format!("gather_masked_k{k_bucket}");
        if !self.session.manifest().executables.contains_key(&name) {
            bail!("no {name} artifact (re-run make artifacts)");
        }
        let flat_idx: Vec<i32> = idx.iter().flatten().copied().collect();
        let flat_mask: Vec<f32> = mask.iter().flatten().copied().collect();
        let idx_dev = self
            .session
            .upload_i32(&[cfg.n_layers, k_bucket], &flat_idx)?;
        let mask_dev = self
            .session
            .upload_f32(&[cfg.n_layers, k_bucket], &flat_mask)?;
        let mut args: Vec<&DeviceTensor> =
            vec![self.weights.get("w1"), self.weights.get("w2")];
        if cfg.is_glu {
            args.push(self.weights.get("wg"));
        }
        args.push(&idx_dev);
        args.push(&mask_dev);
        let outs = self.session.run(&name, &args)?;
        t.record_into(&self.metrics.gather_latency);
        Ok(self.make_pruned(outs, k_bucket))
    }

    /// GRIFFIN selection for one sequence (paper §4.2) or any stats set.
    pub fn select(&self, stats: &LayerStats, keep: f64, strategy: Strategy)
                  -> Result<Vec<Vec<i32>>> {
        let t = Timer::start();
        let k = self.k_for(keep)?;
        let idx = selection::select_experts(stats, k, strategy);
        t.record_into(&self.metrics.selection_latency);
        Ok(idx)
    }

    /// Uniform FF widths with a compiled `decode_pruned` executable at
    /// this batch bucket, ascending.
    fn compiled_uniform_ks(&self, batch: usize) -> Vec<usize> {
        let mut ks: Vec<usize> = self
            .session
            .manifest()
            .executables
            .values()
            .filter(|e| {
                e.kind == "decode_pruned" && e.batch == Some(batch)
            })
            .filter_map(|e| e.k)
            .collect();
        ks.sort_unstable();
        ks.dedup();
        ks
    }

    /// Ragged per-layer profiles with a compiled `decode_pruned_ragged`
    /// executable at this batch bucket.
    pub fn compiled_ragged_profiles(&self, batch: usize)
                                    -> Vec<Vec<usize>> {
        let mut profs: Vec<Vec<usize>> = self
            .session
            .manifest()
            .executables
            .values()
            .filter(|e| {
                e.kind == "decode_pruned_ragged" && e.batch == Some(batch)
            })
            .filter_map(|e| e.layer_ks.clone())
            .collect();
        profs.sort();
        profs.dedup();
        profs
    }

    /// Resolve the served per-layer keep profile for an adaptive-layer
    /// request at this batch bucket: anchor the global budget at the
    /// uniform bucket the keep snaps to (L · k_bucket FLOPs — matched
    /// to what a uniform request at the same keep would spend), allocate
    /// it across depth from the aggregate flocking statistics
    /// (`selection::allocate_layer_budget`, floors/ceilings at the
    /// compiled sweep's extremes), then snap the allocator's target to
    /// the nearest compiled profile — ragged tilts and uniform buckets
    /// compete on equal footing, so near-uniform statistics degrade to
    /// the plain uniform executable rather than forcing a tilt.
    pub fn adaptive_layer_profile(&self, batch: usize, stats: &LayerStats,
                                  keep: f64) -> Result<Vec<usize>> {
        let t = Timer::start();
        let cfg = self.config();
        let l_n = cfg.n_layers;
        let keep_b = self.bucket_keep(batch, keep)?;
        let bucket_k = (cfg.d_ff as f64 * keep_b).round() as usize;
        let uniform = self.compiled_uniform_ks(batch);
        let (floor, ceil) = match (uniform.first(), uniform.last()) {
            (Some(&f), Some(&c)) => (f, c),
            _ => bail!("no decode_pruned executables at batch={batch}"),
        };
        let target = selection::allocate_layer_budget(
            stats, l_n * bucket_k, floor, ceil);
        let mut cands = self.compiled_ragged_profiles(batch);
        for &k in &uniform {
            cands.push(vec![k; l_n]);
        }
        let prof = snap_profile(&cands, &target)
            .context("no servable keep profiles")?;
        t.record_into(&self.metrics.selection_latency);
        Ok(prof)
    }

    /// Selection + gather for a Griffin-family mode over aggregate
    /// stats at this batch bucket. Uniform strategies snap the keep to
    /// a compiled bucket and gather one shared width; adaptive-layer
    /// allocates the same global budget across depth and gathers the
    /// snapped per-layer profile. Returns (weights, k_used, per-layer
    /// widths) — widths are Some exactly when the mode is
    /// adaptive-layer, even if the profile snapped to uniform (the
    /// response provenance must disclose what was actually served).
    pub fn griffin_weights(&mut self, batch: usize, stats: &LayerStats,
                           keep: f64, strategy: Strategy)
                           -> Result<(Rc<PrunedWeights>, usize,
                                      Option<Vec<usize>>)> {
        if let Strategy::AdaptiveLayer = strategy {
            let prof = self.adaptive_layer_profile(batch, stats, keep)?;
            let uniform = prof.windows(2).all(|w| w[0] == w[1]);
            let pw = if uniform {
                // at one shared width the adaptive selection IS top-k;
                // route onto the uniform executable family so it
                // batches (and caches) with plain griffin traffic
                let idx = selection::select_experts(
                    stats, prof[0], Strategy::TopK);
                self.gather_cached(&idx)?
            } else {
                let idx = selection::select_experts_ragged(stats, &prof);
                self.gather_ragged_cached(&idx)?
            };
            let k = pw.k;
            Ok((pw, k, Some(prof)))
        } else {
            let keep = self.bucket_keep(batch, keep)?;
            let idx = self.select(stats, keep, strategy)?;
            let pw = self.gather_cached(&idx)?;
            let k = pw.k;
            Ok((pw, k, None))
        }
    }

    /// Static magnitude expert set (cached; prompt-independent).
    pub fn magnitude_experts(&mut self, keep: f64) -> Result<Vec<Vec<i32>>> {
        if self.magnitude_keep == keep {
            if let Some(idx) = &self.magnitude_cache {
                return Ok(idx.clone());
            }
        }
        let cfg = self.config().clone();
        let w1 = self.host_weights["w1"].to_f32()?;
        let wg = if cfg.is_glu {
            Some(self.host_weights["wg"].to_f32()?)
        } else {
            None
        };
        let metric = selection::magnitude_metric(
            &w1, wg.as_deref(), cfg.n_layers, cfg.d_ff, cfg.d_model);
        let k = self.k_for(keep)?;
        let idx = selection::select_experts(&metric, k, Strategy::TopK);
        self.magnitude_cache = Some(idx.clone());
        self.magnitude_keep = keep;
        Ok(idx)
    }

    /// Adaptive-Wanda masked FF weights for one sequence (uploads
    /// full-size masked copies; unstructured baseline, §5.1).
    pub fn wanda_weights(&self, xnorm: &LayerStats, znorm: &LayerStats,
                         keep: f64) -> Result<FfOverride> {
        if keep.is_nan() || keep <= 0.0 || keep > 1.0 {
            bail!("keep {keep} outside (0,1]");
        }
        let cfg = self.config();
        let (l_n, f, d) = (cfg.n_layers, cfg.d_ff, cfg.d_model);
        let mask_stack = |w: &mut Vec<f32>, norms: &LayerStats,
                          rows: usize, cols: usize| {
            for l in 0..l_n {
                selection::wanda_mask_rows(
                    &mut w[l * rows * cols..(l + 1) * rows * cols],
                    &norms[l], rows, cols, keep);
            }
        };
        let mut out = Vec::new();
        let mut w1 = self.host_weights["w1"].to_f32()?;
        mask_stack(&mut w1, xnorm, f, d);
        out.push(Rc::new(self.session.upload_f32(&[l_n, f, d], &w1)?));
        let mut w2 = self.host_weights["w2"].to_f32()?;
        mask_stack(&mut w2, znorm, d, f);
        out.push(Rc::new(self.session.upload_f32(&[l_n, d, f], &w2)?));
        if cfg.is_glu {
            let mut wg = self.host_weights["wg"].to_f32()?;
            mask_stack(&mut wg, xnorm, f, d);
            out.push(Rc::new(self.session.upload_f32(&[l_n, f, d], &wg)?));
        }
        Ok(FfOverride { tensors: out, id: self.next_set_id() })
    }

    // ------------------------------------------------------------------
    // generation phase
    // ------------------------------------------------------------------

    /// One decode step (low-level; the experiment drivers also use this
    /// directly for fixed-expert ablations). `ff` selects the weight set:
    ///   None -> full model decode_b{B}
    ///   Some(pruned) -> decode_pruned_b{B}_k{K}
    /// `override_ff` (Wanda) replaces the full FF stacks in-place.
    ///
    /// Downloads the full `[B, vocab]` logits for host-side sampling —
    /// the generality/eval path. The serving hot loop prefers
    /// `decode_sample_step`, which keeps logits on device.
    pub fn decode_step(
        &self,
        state: &mut DecodeState,
        tokens: &[i32],
        ff: Option<&PrunedWeights>,
        override_ff: Option<&FfOverride>,
    ) -> Result<Vec<f32>> {
        let t = Timer::start();
        let b = state.batch;
        let tok_dev = self.session.upload_i32(&[b], tokens)?;
        let pos_dev = self.session.upload_i32(&[b], &state.pos)?;
        let plan = self.decode_plan(b, ff, override_ff, false)?;
        let mut outs = self.session.run_prepared(
            &plan, &[&state.kcache, &state.vcache, &tok_dev, &pos_dev])?;
        let vcache = outs.pop().unwrap();
        let kcache = outs.pop().unwrap();
        let logits = self.session.download_f32(&outs.pop().unwrap())?;
        state.kcache = kcache;
        state.vcache = vcache;
        for p in state.pos.iter_mut() {
            *p += 1;
        }
        // the host path advances pos outside the fused chain — any
        // device-resident copy is now stale
        state.invalidate_pos();
        t.record_into(&self.metrics.decode_step_latency);
        Ok(logits)
    }

    /// One fused decode+sample step (`decode_sample_b{B}` /
    /// `decode_pruned_sample_b{B}_k{K}`): sampling runs on device, so
    /// the `[B, vocab]` logits never cross the host boundary — only the
    /// sampled token ids and their logprobs (O(B) bytes) come back.
    ///
    /// `host_tokens` supplies the decode input when the device-resident
    /// tokens from the previous step are stale (first step after
    /// prefill, or any slot-membership change); pass `None` to chain
    /// the previous step's sampled tokens without any token upload.
    ///
    /// `override_ff` (Wanda) replaces the full FF stacks in-place, as in
    /// [`Engine::decode_step`] — the fused `decode_sample_b{B}`
    /// executable takes the same full-size weight ABI, so the masked
    /// copies bind as its static prefix and Wanda rides the on-device
    /// sampling path like every other full-width mode.
    pub fn decode_sample_step(
        &self,
        state: &mut DecodeState,
        samp: &mut SamplingState,
        host_tokens: Option<&[i32]>,
        ff: Option<&PrunedWeights>,
        override_ff: Option<&FfOverride>,
    ) -> Result<(Vec<i32>, Vec<f32>)> {
        let t = Timer::start();
        let b = state.batch;
        if samp.batch != b {
            bail!("sampling state batch {} != decode batch {b}",
                  samp.batch);
        }
        let uploaded;
        let tok_dev: &DeviceTensor = match host_tokens {
            Some(toks) => {
                uploaded = self.session.upload_i32(&[b], toks)?;
                &uploaded
            }
            None => samp.tokens.as_ref().context(
                "no device-resident tokens; pass host_tokens after a \
                 membership change")?,
        };
        // chained-pos ABI: regenerated artifacts output pos + 1, so the
        // device copy carries across ticks and the host mirror is only
        // uploaded to seed the chain (or per step on pre-chain ABIs)
        let chained_abi = self
            .fused_decode_spec_for(b, ff)
            .map(|s| s.outputs.last().is_some_and(|o| o.name == "pos"))
            .unwrap_or(false);
        let uploaded_pos;
        let pos_arg: &DeviceTensor = match &state.pos_dev {
            Some(p) if chained_abi => p,
            _ => {
                uploaded_pos = self.session.upload_i32(&[b], &state.pos)?;
                &uploaded_pos
            }
        };
        let plan = self.decode_plan(b, ff, override_ff, true)?;
        let mut outs = self.session.run_prepared(
            &plan,
            &[&state.kcache, &state.vcache, tok_dev, pos_arg,
              &samp.temp, &samp.topk, &samp.rng],
        )?;
        // outputs: token, logprob, kcache, vcache, rng[, pos]
        let pos_out = if chained_abi { outs.pop() } else { None };
        let rng = outs.pop().unwrap();
        let vcache = outs.pop().unwrap();
        let kcache = outs.pop().unwrap();
        let lp_t = outs.pop().unwrap();
        let tok_t = outs.pop().unwrap();
        let tokens = self.session.download_i32(&tok_t)?;
        let logprobs = self.session.download_f32(&lp_t)?;
        state.kcache = kcache;
        state.vcache = vcache;
        for p in state.pos.iter_mut() {
            *p += 1;
        }
        state.pos_dev = pos_out;
        samp.rng = rng;
        samp.tokens = Some(tok_t);
        t.record_into(&self.metrics.decode_step_latency);
        Ok((tokens, logprobs))
    }

    /// Executable name of the decode variant serving this weight set:
    /// full / uniform-pruned / ragged-pruned, host or fused.
    fn decode_exe_name(b: usize, ff: Option<&PrunedWeights>, fused: bool)
                       -> String {
        match ff {
            Some(p) => {
                let suffix = match &p.layer_ks {
                    Some(lks) => format!("l{}", profile_frag(lks)),
                    None => format!("k{}", p.k),
                };
                if fused {
                    format!("decode_pruned_sample_b{b}_{suffix}")
                } else {
                    format!("decode_pruned_b{b}_{suffix}")
                }
            }
            None => {
                if fused {
                    format!("decode_sample_b{b}")
                } else {
                    format!("decode_b{b}")
                }
            }
        }
    }

    /// The fused decode executable for this (batch, k) combination, if
    /// the artifacts provide one (older artifact sets predate the
    /// fused-sampling ABI — callers fall back to the host path).
    pub fn fused_decode_spec(&self, batch: usize, k: Option<usize>)
                             -> Option<&ExecutableSpec> {
        let name = match k {
            Some(k) => format!("decode_pruned_sample_b{batch}_k{k}"),
            None => format!("decode_sample_b{batch}"),
        };
        self.session.manifest().executables.get(&name)
    }

    /// The fused decode executable serving this exact weight set (the
    /// ragged-aware counterpart of [`Engine::fused_decode_spec`]).
    pub fn fused_decode_spec_for(&self, batch: usize,
                                 ff: Option<&PrunedWeights>)
                                 -> Option<&ExecutableSpec> {
        self.session
            .manifest()
            .executables
            .get(&Self::decode_exe_name(batch, ff, true))
    }

    /// The compiled speculative-verify executable for this (batch,
    /// draft-bucket) combination, if the artifacts provide one.
    pub fn verify_spec(&self, batch: usize, d: usize)
                       -> Option<&ExecutableSpec> {
        self.session
            .manifest()
            .executables
            .get(&format!("verify_b{batch}_s{d}"))
    }

    /// Draft-length buckets with a compiled `verify_b{batch}_s{D}`
    /// executable, ascending (specdec::snap_draft_bucket input). Empty
    /// on artifact sets that predate the speculative ABI — the
    /// scheduler then never takes a spec tick.
    pub fn verify_buckets(&self, batch: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .session
            .manifest()
            .executables
            .values()
            .filter(|e| e.kind == "verify" && e.batch == Some(batch))
            .filter_map(|e| e.seq)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// One speculative verify dispatch (`verify_b{B}_s{D}`): a FULL-
    /// model forward over D positions per slot. `tokens` is the [B, D]
    /// row-major verify window — column 0 is each slot's pending token
    /// (the last emitted, not yet in KV), columns 1..D the drafts the
    /// pruned model proposed — and `state.pos` is the write position of
    /// column 0 (the caller must have rewound any draft-phase pos
    /// advance first). Returns the [B, D, V] per-position logits.
    ///
    /// KV after this call holds full-model K/V for all D positions of
    /// every slot; the caller advances each slot's `pos` by its emitted
    /// count, which both commits the accepted prefix and "rolls back"
    /// the rejected rows — they sit beyond `pos`, are never attendable
    /// (decode masks kpos <= pos), and get overwritten by later steps.
    /// No splice, no device traffic for rollback.
    pub fn verify_step(&self, state: &mut DecodeState, tokens: &[i32],
                       d: usize) -> Result<Vec<f32>> {
        let t = Timer::start();
        let b = state.batch;
        if tokens.len() != b * d {
            bail!("verify_step: {} tokens for [{b}, {d}] window",
                  tokens.len());
        }
        let name = format!("verify_b{b}_s{d}");
        if !self.session.manifest().executables.contains_key(&name) {
            bail!("no {name} executable (re-run make artifacts)");
        }
        let tok_dev = self.session.upload_i32(&[b, d], tokens)?;
        let pos_dev = self.session.upload_i32(&[b], &state.pos)?;
        let plan = self.base_plan(&name)?;
        let mut outs = self.session.run_prepared(
            &plan, &[&state.kcache, &state.vcache, &tok_dev, &pos_dev])?;
        let vcache = outs.pop().unwrap();
        let kcache = outs.pop().unwrap();
        let logits = self.session.download_f32(&outs.pop().unwrap())?;
        state.kcache = kcache;
        state.vcache = vcache;
        // pos is left to the caller (advance-by-emitted); either way
        // the device pos chain no longer matches the host mirror
        state.invalidate_pos();
        t.record_into(&self.metrics.verify_latency);
        Ok(logits)
    }

    /// Resolve (and cache) a prepared dispatch plan whose static prefix
    /// is the base weight set (verify and other full-weight
    /// executables outside the decode family). Base plans (set id 0)
    /// pin nothing beyond the WeightStore, so they bypass the LRU
    /// accounting in [`Engine::decode_plan`].
    fn base_plan(&self, name: &str) -> Result<Rc<DispatchPlan>> {
        let tick = self.plan_ticks.get() + 1;
        self.plan_ticks.set(tick);
        let key = (name.to_string(), 0u64);
        if let Some(entry) = self.plans.borrow_mut().get_mut(&key) {
            entry.0 = tick;
            return Ok(entry.1.clone());
        }
        let plan =
            Rc::new(self.session.prepare(name, self.weights.ordered_rc())?);
        self.plans.borrow_mut().insert(key, (tick, plan.clone()));
        Ok(plan)
    }

    /// Build the device-resident per-slot sampling state: one
    /// (spec, xorshift32 state) pair per slot (pad free slots with
    /// `(SamplerSpec::Greedy, sampling::seed_state(0))`).
    pub fn new_sampling_state(&self, slots: &[(SamplerSpec, u32)])
                              -> Result<SamplingState> {
        let b = slots.len();
        let mut temp = vec![0f32; b];
        let mut topk = vec![1i32; b];
        let mut rng = vec![0i32; b];
        for (i, (spec, state)) in slots.iter().enumerate() {
            let (t, k) = device_params(*spec);
            temp[i] = t;
            topk[i] = k;
            rng[i] = *state as i32;
        }
        Ok(SamplingState {
            temp: self.session.upload_f32(&[b], &temp)?,
            topk: self.session.upload_i32(&[b], &topk)?,
            rng: self.session.upload_i32(&[b], &rng)?,
            tokens: None,
            batch: b,
        })
    }

    /// Resolve (and cache) the prepared dispatch plan for one decode
    /// variant. Plans are keyed by (executable, weight-set id), so a
    /// steady-state decode loop re-binds nothing and a pool alternating
    /// between cached selections reuses both plans; non-base entries
    /// are LRU-bounded (each pins its weight set via Rc).
    fn decode_plan(&self, b: usize, ff: Option<&PrunedWeights>,
                   override_ff: Option<&FfOverride>, fused: bool)
                   -> Result<Rc<DispatchPlan>> {
        let name = Self::decode_exe_name(b, ff, fused);
        let set_id = match ff {
            Some(p) => p.id,
            None => override_ff.map_or(0, |o| o.id),
        };
        let tick = self.plan_ticks.get() + 1;
        self.plan_ticks.set(tick);
        let key = (name.clone(), set_id);
        if let Some(entry) = self.plans.borrow_mut().get_mut(&key) {
            entry.0 = tick;
            return Ok(entry.1.clone());
        }
        let static_args: Vec<Rc<DeviceTensor>> = match ff {
            Some(p) => {
                let mut v = self.weights.ordered_rc_nonff();
                v.extend(p.tensors.iter().cloned());
                v
            }
            None => match override_ff {
                None => self.weights.ordered_rc(),
                Some(o) => self
                    .weights
                    .param_order
                    .iter()
                    .map(|pname| match pname.as_str() {
                        "w1" => o.tensors[0].clone(),
                        "w2" => o.tensors[1].clone(),
                        "wg" => o.tensors[2].clone(),
                        _ => self.weights.get_rc(pname),
                    })
                    .collect(),
            },
        };
        let plan = Rc::new(self.session.prepare(&name, static_args)?);
        let mut plans = self.plans.borrow_mut();
        // non-base plans pin a whole pruned/override weight set via Rc.
        // First drop plans whose set is owned ONLY by cached plans —
        // several plans (fused + host variant) can co-own one set, so
        // liveness is strong_count vs the number of referencing plans,
        // not strong_count == 1 — then bound the survivors with a small
        // LRU so executables that are never dispatched again cannot
        // hold weights for the engine's lifetime. Base-weight plans
        // (set 0) pin nothing extra: the WeightStore co-owns those
        // tensors, so they never look dead.
        let plan_refs: BTreeMap<*const DeviceTensor, usize> = {
            let mut m = BTreeMap::new();
            for (_, p) in plans.values() {
                for t in p.static_args() {
                    *m.entry(Rc::as_ptr(t)).or_insert(0) += 1;
                }
            }
            m
        };
        // two-pass: decide liveness on this consistent snapshot FIRST,
        // then remove — removing inside a single retain would decrement
        // strong_counts mid-sweep and let the second co-owning plan of
        // a dead set survive the pass
        let dead: Vec<(String, u64)> = plans
            .iter()
            .filter(|((_, id), _)| *id != 0)
            .filter(|(_, (_, p))| {
                p.static_args().iter().any(|t| {
                    Rc::strong_count(t) == plan_refs[&Rc::as_ptr(t)]
                })
            })
            .map(|(k, _)| k.clone())
            .collect();
        for k in &dead {
            plans.remove(k);
        }
        if set_id != 0 {
            let nonbase =
                plans.keys().filter(|(_, id)| *id != 0).count();
            if nonbase >= PLAN_CACHE_CAP {
                if let Some(victim) = plans
                    .iter()
                    .filter(|((_, id), _)| *id != 0)
                    .min_by_key(|(_, (t, _))| *t)
                    .map(|(k, _)| k.clone())
                {
                    plans.remove(&victim);
                }
            }
        }
        plans.insert(key, (tick, plan.clone()));
        Ok(plan)
    }

    // ------------------------------------------------------------------
    // slot-state management (continuous batching; scheduler.rs)
    // ------------------------------------------------------------------

    /// KV-cache shape of the compiled decode executable for `batch`
    /// ([L, B, H, Smax, dh] — aot.py cache_spec).
    pub fn decode_cache_shape(&self, batch: usize) -> Result<Vec<usize>> {
        let name = format!("decode_b{batch}");
        let spec = self
            .session
            .manifest()
            .executables
            .get(&name)
            .with_context(|| format!("no decode executable for b={batch}"))?;
        spec.inputs
            .iter()
            .find(|io| io.name == "kcache")
            .map(|io| io.shape.clone())
            .with_context(|| format!("{name}: no kcache input"))
    }

    /// Allocate an empty persistent decode state for a slot pool of
    /// `batch` slots (zeroed KV cache, all positions 0).
    pub fn new_decode_state(&self, batch: usize) -> Result<DecodeState> {
        let shape = self.decode_cache_shape(batch)?;
        let zeros = vec![0f32; shape.iter().product()];
        Ok(DecodeState {
            kcache: self.session.upload_f32(&shape, &zeros)?,
            vcache: self.session.upload_f32(&shape, &zeros)?,
            pos: vec![0; batch],
            pos_dev: None,
            batch,
        })
    }

    /// Validate splice operands; returns (layers, dst_batch, src_batch,
    /// row elements) for the routed paths.
    fn check_splice(dst: &DecodeState, src_k: &DeviceTensor,
                    pairs: &[(usize, usize)])
                    -> Result<(usize, usize, usize, usize)> {
        let ds = &dst.kcache.shape;
        let ss = &src_k.shape;
        if ds.len() != 5 || ss.len() != 5 {
            bail!("splice_slots: expected [L,B,H,S,dh] caches");
        }
        if ds[0] != ss[0] || ds[2..] != ss[2..] {
            bail!("splice_slots: incompatible cache shapes {ds:?} vs {ss:?}");
        }
        let (layers, db, sb) = (ds[0], ds[1], ss[1]);
        let row: usize = ds[2..].iter().product();
        for &(si, di) in pairs {
            if si >= sb || di >= db {
                bail!("splice_slots: pair ({si},{di}) out of range \
                       (src b={sb}, dst b={db})");
            }
        }
        Ok((layers, db, sb, row))
    }

    /// The compiled device-side splice for this (src, dst) batch-bucket
    /// pair, if the artifacts provide one.
    pub fn splice_spec(&self, src_b: usize, dst_b: usize)
                       -> Option<&ExecutableSpec> {
        self.session
            .manifest()
            .executables
            .get(&format!("splice_b{src_b}_b{dst_b}"))
    }

    /// Copy freshly prefilled sequences into slots of a persistent decode
    /// state: for each `(src_row, dst_slot)` pair the whole KV row
    /// [L, :, H, Smax, dh] and the write position move from `src` to
    /// `dst`. Routed: when the artifacts provide `splice_b{src}_b{dst}`
    /// the copy is a device-side dynamic-update-slice (the host uploads
    /// only O(dst_batch) index lanes); otherwise the host-staged
    /// fallback downloads and re-uploads both caches (old artifact
    /// sets). Write positions stay host-authoritative either way.
    pub fn splice_slots(&self, dst: &mut DecodeState, src: &DecodeState,
                        pairs: &[(usize, usize)]) -> Result<()> {
        self.splice_rows(dst, &src.kcache, &src.vcache, &src.pos, pairs)
    }

    /// Raw-tensor splice source: like [`Engine::splice_slots`], but the
    /// source rows come from any [L, B, H, Smax, dh] cache pair — a
    /// freshly prefilled admission state, a chunked-prefill
    /// [`ChunkState`], or a prefix-cache entry's retained tensors
    /// (which are `Rc`-shared and never mutated: the substrate is
    /// purely functional, so a splice reads the entry without consuming
    /// it). `src_pos` supplies the per-row write positions.
    pub fn splice_rows(&self, dst: &mut DecodeState,
                       src_k: &DeviceTensor, src_v: &DeviceTensor,
                       src_pos: &[i32], pairs: &[(usize, usize)])
                       -> Result<()> {
        let (_layers, db, sb, _row) = Self::check_splice(dst, src_k,
                                                         pairs)?;
        if src_pos.len() != sb {
            bail!("splice_rows: {} positions for src batch {sb}",
                  src_pos.len());
        }
        if self.splice_spec(sb, db).is_some() {
            self.splice_rows_device(dst, src_k, src_v, src_pos, pairs,
                                    sb, db)
        } else {
            self.splice_rows_host(dst, src_k, src_v, src_pos, pairs)
        }
    }

    /// Device-side splice through the compiled `splice_b{src}_b{dst}`
    /// executable: neither KV cache crosses the host boundary.
    fn splice_rows_device(&self, dst: &mut DecodeState,
                          src_k: &DeviceTensor, src_v: &DeviceTensor,
                          src_pos: &[i32], pairs: &[(usize, usize)],
                          sb: usize, db: usize) -> Result<()> {
        let t = Timer::start();
        let name = format!("splice_b{sb}_b{db}");
        // untaken lanes keep their resident row (take = 0); their
        // src_idx of 0 is never read
        let mut idx = vec![0i32; db];
        let mut take = vec![0i32; db];
        for &(si, di) in pairs {
            idx[di] = si as i32;
            take[di] = 1;
        }
        let idx_dev = self.session.upload_i32(&[db], &idx)?;
        let take_dev = self.session.upload_i32(&[db], &take)?;
        let mut outs = self.session.run(
            &name,
            &[&dst.kcache, &dst.vcache, src_k, src_v,
              &idx_dev, &take_dev],
        )?;
        let vcache = outs.pop().unwrap();
        let kcache = outs.pop().unwrap();
        dst.kcache = kcache;
        dst.vcache = vcache;
        for &(si, di) in pairs {
            dst.pos[di] = src_pos[si];
        }
        // membership changed: the fused chain re-seeds pos from the
        // host mirror on its next step
        dst.invalidate_pos();
        self.metrics.fused_splices.inc();
        t.record_into(&self.metrics.kv_splice_latency);
        Ok(())
    }

    /// Host-staged splice fallback over a [`DecodeState`] source
    /// (download + re-upload of both caches). Public so parity tests
    /// can pin device-path equivalence; serving paths go through the
    /// routed [`Engine::splice_slots`].
    pub fn splice_slots_host(&self, dst: &mut DecodeState,
                             src: &DecodeState, pairs: &[(usize, usize)])
                             -> Result<()> {
        self.splice_rows_host(dst, &src.kcache, &src.vcache, &src.pos,
                              pairs)
    }

    fn splice_rows_host(&self, dst: &mut DecodeState,
                        src_k: &DeviceTensor, src_v: &DeviceTensor,
                        src_pos: &[i32], pairs: &[(usize, usize)])
                        -> Result<()> {
        let t = Timer::start();
        let (layers, db, sb, row) = Self::check_splice(dst, src_k,
                                                       pairs)?;
        let ds = dst.kcache.shape.clone();
        let mut dk = self.session.download_f32(&dst.kcache)?;
        let mut dv = self.session.download_f32(&dst.vcache)?;
        let sk = self.session.download_f32(src_k)?;
        let sv = self.session.download_f32(src_v)?;
        for l in 0..layers {
            for &(si, di) in pairs {
                let s0 = (l * sb + si) * row;
                let d0 = (l * db + di) * row;
                dk[d0..d0 + row].copy_from_slice(&sk[s0..s0 + row]);
                dv[d0..d0 + row].copy_from_slice(&sv[s0..s0 + row]);
            }
        }
        dst.kcache = self.session.upload_f32(&ds, &dk)?;
        dst.vcache = self.session.upload_f32(&ds, &dv)?;
        for &(si, di) in pairs {
            dst.pos[di] = src_pos[si];
        }
        dst.invalidate_pos();
        t.record_into(&self.metrics.kv_splice_latency);
        Ok(())
    }

    // ------------------------------------------------------------------
    // chunked positioned prefill (prefix-cache tails + long prompts)
    // ------------------------------------------------------------------

    /// Positioned prefill seq buckets (`prefill_sample_b1_s{S}_p`),
    /// ascending. Empty on artifact sets that predate the chunked
    /// admission ABI.
    pub fn positioned_buckets(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .session
            .manifest()
            .executables
            .values()
            .filter(|e| {
                e.kind == "prefill_sample_positioned"
                    && e.batch == Some(1)
            })
            .filter_map(|e| e.seq)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Block granule of the chunked admission path: the smallest
    /// positioned bucket. Chunk starts and prefix-cache boundaries are
    /// aligned to it. None = no chunked ABI.
    pub fn chunk_block(&self) -> Option<usize> {
        self.positioned_buckets().first().copied()
    }

    /// Does the manifest provide the positioned prefill family (chunked
    /// tails, prefix-cache splicing, over-bucket prompts)?
    pub fn can_chunk_prefill(&self) -> bool {
        self.chunk_block().is_some()
    }

    /// Compiled sampler truncation cap of the positioned prefill family
    /// (min over buckets, mirroring [`Engine::fused_prefill_cap`]):
    /// only fused-eligible samplers can admit through the chunked path,
    /// because the final chunk samples the first token on device.
    pub fn chunked_prefill_cap(&self) -> Option<usize> {
        self.session
            .manifest()
            .executables
            .values()
            .filter(|e| {
                e.kind == "prefill_sample_positioned"
                    && e.batch == Some(1)
            })
            .map(|e| e.sample_topk.unwrap_or(crate::sampling::SAMPLE_TOPK))
            .min()
    }

    /// Largest prompt a SINGLE-dispatch admission can serve: the max
    /// compiled prefill-family seq bucket. Prompts beyond it must be
    /// chunk-prefilled (positioned family) or rejected at admission
    /// with a typed `invalid_request` — never silently truncated.
    pub fn single_shot_prompt_cap(&self) -> Option<usize> {
        self.session
            .manifest()
            .executables
            .values()
            .filter(|e| {
                e.kind == "prefill" || e.kind == "prefill_sample"
            })
            .filter_map(|e| e.seq)
            .max()
    }

    /// Fresh chunk state: zero KV caches and zero running sums. The
    /// zero tensors are uploaded once and `Rc`-shared across every cold
    /// chunked admission (the substrate never mutates inputs), so cold
    /// chunked admission traffic stays proportional to the prompt, not
    /// to Smax.
    pub fn new_chunk_state(&self) -> Result<ChunkState> {
        if let Some(z) = self.chunk_zero.borrow().as_ref() {
            return Ok(z.clone());
        }
        let spec = self
            .session
            .manifest()
            .executables
            .values()
            .find(|e| {
                e.kind == "prefill_sample_positioned"
                    && e.batch == Some(1)
            })
            .context("no positioned prefill executables \
                      (chunked admission unavailable)")?;
        let shape_of = |name: &str| -> Result<Vec<usize>> {
            spec.inputs
                .iter()
                .find(|io| io.name == name)
                .map(|io| io.shape.clone())
                .with_context(|| {
                    format!("{}: no {name} input", spec.name)
                })
        };
        let zeros = |shape: Vec<usize>| -> Result<Rc<DeviceTensor>> {
            let z = vec![0f32; shape.iter().product()];
            Ok(Rc::new(self.session.upload_f32(&shape, &z)?))
        };
        let state = ChunkState {
            kcache: zeros(shape_of("kcache")?)?,
            vcache: zeros(shape_of("vcache")?)?,
            stats: zeros(shape_of("stats_in")?)?,
            xnorms: zeros(shape_of("xnorms_in")?)?,
            znorms: zeros(shape_of("znorms_in")?)?,
            filled: 0,
        };
        *self.chunk_zero.borrow_mut() = Some(state.clone());
        Ok(state)
    }

    /// Plan the positioned chunk sizes covering prompt rows
    /// [`from`, `len`): every chunk but the last is block-aligned and
    /// fully valid, and the FINAL chunk starts at the last block
    /// boundary strictly before `len` — so the state right before it is
    /// the block-aligned snapshot the prefix cache retains, and its
    /// sampled token (over row `len - 1`) is the request's first.
    /// `from` must be block-aligned (0 or a prefix-cache boundary).
    pub fn plan_chunks(&self, from: usize, len: usize)
                       -> Result<Vec<usize>> {
        let buckets = self.positioned_buckets();
        let block = *buckets
            .first()
            .context("no positioned prefill buckets")?;
        if from % block != 0 {
            bail!("chunk start {from} not aligned to block {block}");
        }
        if len <= from {
            bail!("chunk plan: prompt len {len} <= start {from}");
        }
        let max_seq = self.config().max_seq;
        if len > max_seq {
            bail!("prompt of {len} tokens exceeds max_seq {max_seq}");
        }
        // where the final (sampling) chunk starts
        let boundary = ((len - 1) / block) * block;
        let mut plan = Vec::new();
        let mut cur = from;
        while cur < boundary {
            // largest block-multiple bucket fitting the aligned span
            let s = buckets
                .iter()
                .copied()
                .filter(|&s| s % block == 0 && cur + s <= boundary)
                .max()
                .unwrap_or(block);
            plan.push(s);
            cur += s;
        }
        let tail = len - boundary; // in [1, block]
        let s = buckets
            .iter()
            .copied()
            .filter(|&s| s >= tail)
            .min()
            .with_context(|| format!("no positioned bucket >= {tail}"))?;
        plan.push(s);
        Ok(plan)
    }

    /// One positioned prefill dispatch: run the next `chunk.len()`
    /// prompt rows (absolute positions [state.filled, state.filled +
    /// chunk.len())) through `prefill_sample_b1_s{S}_p`, threading the
    /// KV caches and the running pre-sqrt statistic sums through the
    /// state. `sampler` carries the request's device sampling lane for
    /// the FINAL chunk; pass `None` on intermediate chunks (a greedy
    /// dummy lane whose sampled token is discarded — the caller's host
    /// mirror must still `skip()` once per FINAL chunk only, since the
    /// dummy lanes never consume the request's stream). Returns the
    /// sampled (token, logprob) of the chunk's last valid row.
    pub fn prefill_chunk(&self, state: &mut ChunkState, chunk: &[i32],
                         sampler: Option<(SamplerSpec, u32)>)
                         -> Result<(i32, f32)> {
        let t = Timer::start();
        let valid = chunk.len();
        if valid == 0 {
            bail!("prefill_chunk: empty chunk");
        }
        let s = self
            .positioned_buckets()
            .into_iter()
            .filter(|&s| s >= valid)
            .min()
            .with_context(|| format!("no positioned bucket >= {valid}"))?;
        let name = format!("prefill_sample_b1_s{s}_p");
        let mut toks = chunk.to_vec();
        toks.resize(s, PAD_ID);
        let toks_dev = self.session.upload_i32(&[1, s], &toks)?;
        let lens_dev = self.session.upload_i32(&[1], &[valid as i32])?;
        let start_dev =
            self.session.upload_i32(&[1], &[state.filled as i32])?;
        let (spec, seed) =
            sampler.unwrap_or((SamplerSpec::Greedy, seed_state(0)));
        let (tv, kv) = device_params(spec);
        let temp_dev = self.session.upload_f32(&[1], &[tv])?;
        let topk_dev = self.session.upload_i32(&[1], &[kv])?;
        let rng_dev = self.session.upload_i32(&[1], &[seed as i32])?;
        let mut args: Vec<&DeviceTensor> = self.weights.ordered();
        args.push(&state.kcache);
        args.push(&state.vcache);
        args.push(&state.stats);
        args.push(&state.xnorms);
        args.push(&state.znorms);
        args.push(&toks_dev);
        args.push(&lens_dev);
        args.push(&start_dev);
        args.push(&temp_dev);
        args.push(&topk_dev);
        args.push(&rng_dev);
        let mut outs = self.session.run(&name, &args)?;
        // outputs: token, logprob, kcache, vcache, stats, xnorms,
        // znorms, rng — the rng output is discarded like in
        // prefill_sample (host mirrors are the stream's source of truth)
        let _rng_out = outs.pop().unwrap();
        state.znorms = Rc::new(outs.pop().unwrap());
        state.xnorms = Rc::new(outs.pop().unwrap());
        state.stats = Rc::new(outs.pop().unwrap());
        state.vcache = Rc::new(outs.pop().unwrap());
        state.kcache = Rc::new(outs.pop().unwrap());
        let lp = self.session.download_f32(&outs.pop().unwrap())?[0];
        let tok = self.session.download_i32(&outs.pop().unwrap())?[0];
        state.filled += valid;
        self.metrics.prompt_tokens.add(valid as u64);
        t.record_into(&self.metrics.prefill_latency);
        Ok((tok, lp))
    }

    /// Finalize the selection statistics of a completed chunked
    /// prefill: download the running pre-sqrt sums the mode needs and
    /// apply the sqrt on the host. f32 sqrt is correctly rounded (IEEE
    /// 754), so the result is bit-identical to the device-side sqrt the
    /// single-shot prefill applies (pinned by runtime::cpu
    /// `positioned_chunks_match_single_shot_prefill_bitwise`).
    pub fn chunk_stats(&self, state: &ChunkState, needs: StatNeeds)
                       -> Result<(Option<LayerStats>, Option<LayerStats>,
                                  Option<LayerStats>)> {
        let cfg = self.config();
        let sqrt_split =
            |t: &DeviceTensor, width: usize| -> Result<LayerStats> {
                let mut rows = self.split_layer_stats(t, width, 1, 1)?;
                let mut stack = rows.pop().unwrap();
                for row in &mut stack {
                    for v in row.iter_mut() {
                        *v = v.sqrt();
                    }
                }
                Ok(stack)
            };
        let stats = if needs.stats {
            Some(sqrt_split(&state.stats, cfg.d_ff)?)
        } else {
            None
        };
        let (xnorms, znorms) = if needs.norms {
            (
                Some(sqrt_split(&state.xnorms, cfg.d_model)?),
                Some(sqrt_split(&state.znorms, cfg.d_ff)?),
            )
        } else {
            (None, None)
        };
        Ok((stats, xnorms, znorms))
    }

    /// Full request: prompt → (select → gather) → generation (paper Fig 3).
    pub fn generate(&mut self, req: &GenRequest) -> Result<GenResponse> {
        let e2e = Timer::start();
        let responses = self.generate_batch(std::slice::from_ref(req))?;
        let mut r = responses.into_iter().next().unwrap();
        r.tokens_per_sec =
            r.tokens.len() as f64 / e2e.elapsed().as_secs_f64();
        Ok(r)
    }

    /// Batched generation. GRIFFIN batches share one expert set via the
    /// eq.7 aggregate (paper §5.3); Full shares nothing; Magnitude is
    /// static; Wanda masks from the aggregate norms. All requests in the
    /// batch must use the same mode.
    pub fn generate_batch(&mut self, reqs: &[GenRequest])
                          -> Result<Vec<GenResponse>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let e2e = Timer::start();
        let mode = reqs[0].mode;
        if reqs.iter().any(|r| r.mode != mode) {
            bail!("generate_batch: mixed modes");
        }
        let cfg = self.config().clone();
        let prompts: Vec<Vec<i32>> =
            reqs.iter().map(|r| r.prompt.clone()).collect();

        let pre_t = Timer::start();
        let mut pre = self.prefill(&prompts, PrefillLogits::LastToken)?;
        let prefill_ms = pre_t.elapsed().as_secs_f64() * 1e3;

        // --- selection phase ------------------------------------------
        let sel_t = Timer::start();
        let (pruned, wanda_ffw, k_used, k_per_layer):
            (Option<Rc<PrunedWeights>>, Option<FfOverride>,
             Option<usize>, Option<Vec<usize>>) = match mode {
            Mode::Full => (None, None, None, None),
            Mode::Griffin { keep, strategy } => {
                let agg = selection::aggregate_stats(
                    &pre.stats
                        .iter()
                        .cloned()
                        .zip(pre.lengths.iter().copied())
                        .collect::<Vec<_>>(),
                );
                // the uniform strategies snap to a keep whose
                // decode_pruned executable exists at this batch
                // bucket; adaptive-layer allocates the matched global
                // budget across depth and snaps to a compiled profile
                let (pw, k, prof) = self.griffin_weights(
                    pre.state.batch, &agg, keep, strategy)?;
                (Some(pw), None, Some(k), prof)
            }
            Mode::Magnitude { keep } => {
                let keep = self.bucket_keep(pre.state.batch, keep)?;
                let idx = self.magnitude_experts(keep)?;
                let pw = self.gather_cached(&idx)?;
                let k = pw.k;
                (Some(pw), None, Some(k), None)
            }
            Mode::Wanda { keep } => {
                // aggregate norms across the batch (rms over sequences)
                let agg_x = aggregate_norms(&pre.xnorms);
                let agg_z = aggregate_norms(&pre.znorms);
                (None, Some(self.wanda_weights(&agg_x, &agg_z, keep)?),
                 None, None)
            }
        };
        let select_ms = sel_t.elapsed().as_secs_f64() * 1e3;

        // --- generation phase -----------------------------------------
        let dec_t = Timer::start();
        let n = reqs.len();
        let b = pre.state.batch;
        let max_new = reqs.iter().map(|r| r.max_new_tokens).max().unwrap();
        let mut samplers: Vec<Sampler> = reqs
            .iter()
            .map(|r| Sampler::new(r.sampler, r.seed))
            .collect();

        // first token comes from the prompt's last logits
        let mut cur: Vec<i32> = vec![PAD_ID; b];
        let mut done = vec![false; b];
        let mut out_tokens: Vec<Vec<i32>> = vec![Vec::new(); n];
        let mut out_lps: Vec<Vec<f32>> = vec![Vec::new(); n];
        let mut finish = vec![FinishReason::Length; n];
        let mut ttft_ms = vec![0f64; n];
        for i in 0..n {
            let t = samplers[i].sample(&pre.last_logits[i]) as i32;
            let lp = log_softmax_at(&pre.last_logits[i], t as usize);
            // first emitted token: TTFT from admission, like the slot
            // scheduler measures it
            ttft_ms[i] =
                reqs[i].admitted_at.elapsed().as_secs_f64() * 1e3;
            cur[i] = t;
            out_tokens[i].push(t);
            out_lps[i].push(lp);
            if reqs[i].stop_at_eos && t == EOS_ID {
                done[i] = true;
                finish[i] = FinishReason::Eos;
            }
        }
        for slot in n..b {
            done[slot] = true; // padding slots never produce output
        }

        let v = cfg.vocab_size;
        for _step in 1..max_new {
            if done.iter().all(|&d| d) {
                break;
            }
            // context-full guard
            for i in 0..n {
                if !done[i]
                    && (pre.state.pos[i] as usize) >= cfg.max_seq
                {
                    done[i] = true;
                    finish[i] = FinishReason::ContextFull;
                }
            }
            if done.iter().all(|&d| d) {
                break;
            }
            let logits = self.decode_step(
                &mut pre.state, &cur, pruned.as_deref(),
                wanda_ffw.as_ref())?;
            for i in 0..n {
                if done[i] || out_tokens[i].len() >= reqs[i].max_new_tokens
                {
                    done[i] = done[i]
                        || out_tokens[i].len() >= reqs[i].max_new_tokens;
                    continue;
                }
                let row = &logits[i * v..(i + 1) * v];
                let t = samplers[i].sample(row) as i32;
                out_lps[i].push(log_softmax_at(row, t as usize));
                out_tokens[i].push(t);
                cur[i] = t;
                if reqs[i].stop_at_eos && t == EOS_ID {
                    done[i] = true;
                    finish[i] = FinishReason::Eos;
                }
            }
        }
        let decode_ms = dec_t.elapsed().as_secs_f64() * 1e3;

        let total_new: usize = out_tokens.iter().map(Vec::len).sum();
        self.metrics.tokens_generated.add(total_new as u64);
        e2e.record_into(&self.metrics.e2e_latency);
        self.metrics.requests_completed.add(n as u64);

        Ok((0..n)
            .map(|i| GenResponse {
                id: reqs[i].id,
                text: self.tokenizer.decode(&out_tokens[i]),
                tokens: std::mem::take(&mut out_tokens[i]),
                logprobs: std::mem::take(&mut out_lps[i]),
                finish: finish[i],
                k_used,
                k_per_layer: k_per_layer.clone(),
                selection: SelectionInfo::from_mode(&mode),
                speculative: None,
                cache: None,
                prefill_ms,
                select_ms,
                decode_ms,
                ttft_ms: ttft_ms[i],
                tokens_per_sec: total_new as f64
                    / (decode_ms / 1e3).max(1e-9),
            })
            .collect())
    }

    /// Fused-scan greedy generation (throughput path): one PJRT call for
    /// the whole generation phase. Only batch=1, greedy, fixed G buckets.
    pub fn generate_scan(&mut self, req: &GenRequest) -> Result<GenResponse> {
        let e2e = Timer::start();
        let cfg = self.config().clone();
        let pre_t = Timer::start();
        let pre = self.prefill(std::slice::from_ref(&req.prompt),
                               PrefillLogits::LastToken)?;
        let prefill_ms = pre_t.elapsed().as_secs_f64() * 1e3;
        if pre.state.batch != 1 {
            bail!("generate_scan requires batch bucket 1");
        }

        let sel_t = Timer::start();
        let (exe_name, pruned, k_used) = match req.mode {
            Mode::Full => {
                let g = self.scan_bucket("generate_scan", None,
                                         req.max_new_tokens)?;
                (format!("generate_scan_b1_g{g}"), None, None)
            }
            Mode::Griffin { keep, strategy } => {
                // snap to a keep compiled for the scan path (aot.py
                // emits generate_scan_pruned only at the headline k)
                let keep =
                    self.snap_keep("generate_scan_pruned", 1, keep)?;
                let idx = self.select(&pre.stats[0], keep, strategy)?;
                let pw = self.gather_cached(&idx)?;
                let k = pw.k;
                let g = self.scan_bucket("generate_scan_pruned", Some(k),
                                         req.max_new_tokens)?;
                (format!("generate_scan_pruned_b1_k{k}_g{g}"), Some(pw),
                 Some(k))
            }
            _ => bail!("generate_scan supports Full and Griffin modes"),
        };
        let select_ms = sel_t.elapsed().as_secs_f64() * 1e3;

        let dec_t = Timer::start();
        let first = crate::sampling::argmax(&pre.last_logits[0]) as i32;
        let ttft_ms = req.admitted_at.elapsed().as_secs_f64() * 1e3;
        let tok_dev = self.session.upload_i32(&[1], &[first])?;
        let pos_dev = self.session.upload_i32(&[1], &pre.state.pos)?;
        let mut args: Vec<&DeviceTensor> = Vec::new();
        match &pruned {
            Some(pw) => {
                args.extend(self.weights.ordered_nonff());
                args.extend(pw.tensors.iter().map(|t| &**t));
            }
            None => args.extend(self.weights.ordered()),
        }
        args.push(&pre.state.kcache);
        args.push(&pre.state.vcache);
        args.push(&tok_dev);
        args.push(&pos_dev);
        let outs = self.session.run(&exe_name, &args)?;
        let scan_tokens = self.session.download_i32(&outs[0])?;
        let scan_lps = self.session.download_f32(&outs[1])?;
        let decode_ms = dec_t.elapsed().as_secs_f64() * 1e3;

        // assemble: first sampled token + scan outputs, truncated at EOS
        let mut tokens = vec![first];
        let mut lps = vec![log_softmax_at(&pre.last_logits[0],
                                          first as usize)];
        let mut finish = FinishReason::Length;
        if req.stop_at_eos && first == EOS_ID {
            finish = FinishReason::Eos;
        } else {
            for (t, lp) in scan_tokens.iter().zip(&scan_lps) {
                if tokens.len() >= req.max_new_tokens {
                    break;
                }
                tokens.push(*t);
                lps.push(*lp);
                if req.stop_at_eos && *t == EOS_ID {
                    finish = FinishReason::Eos;
                    break;
                }
            }
        }
        let _ = cfg;
        self.metrics.tokens_generated.add(tokens.len() as u64);
        e2e.record_into(&self.metrics.e2e_latency);
        self.metrics.requests_completed.inc();
        Ok(GenResponse {
            id: req.id,
            text: self.tokenizer.decode(&tokens),
            tokens,
            logprobs: lps,
            finish,
            k_used,
            // the scan path serves adaptive-layer as uniform top-k at
            // its compiled bucket (no ragged scan executables), so
            // there are no per-layer widths to disclose
            k_per_layer: None,
            selection: SelectionInfo::from_mode(&req.mode),
            speculative: None,
            cache: None,
            prefill_ms,
            select_ms,
            decode_ms,
            ttft_ms,
            tokens_per_sec: 0.0,
        })
    }

    /// Smallest compiled scan bucket with G >= needed-1 (the first token
    /// comes from prefill logits).
    fn scan_bucket(&self, kind: &str, k: Option<usize>, max_new: usize)
                   -> Result<usize> {
        let need = max_new.saturating_sub(1).max(1);
        self.session
            .manifest()
            .executables
            .values()
            .filter(|e| {
                e.kind == kind
                    && e.batch == Some(1)
                    && (k.is_none() || e.k == k)
                    && e.gen.is_some_and(|g| g >= need)
            })
            .filter_map(|e| e.gen)
            .min()
            .with_context(|| {
                format!("no {kind} bucket >= {need} (k={k:?})")
            })
    }

    // ------------------------------------------------------------------
    // teacher-forced scoring (perplexity experiments, Figs 4/5)
    // ------------------------------------------------------------------

    /// Score `continuation` under the model given `prompt`, with the
    /// generation-phase weights chosen by `mode` (experts from the prompt,
    /// as in the paper's language-modeling "simulated generation" setup).
    /// Returns per-token negative log-likelihoods of the continuation.
    pub fn score_continuation(&mut self, prompt: &[i32],
                              continuation: &[i32], mode: Mode)
                              -> Result<Vec<f64>> {
        if prompt.is_empty() || continuation.is_empty() {
            bail!("score_continuation: empty input");
        }
        // scoring needs only the last-token row here (the continuation
        // is teacher-forced through decode steps), but it must stay on
        // the full-logits `prefill` family: the reduced prefill_sample
        // variant samples instead of returning logits, so routing it
        // here would silently lose the scores. Route by need.
        let mut pre = self.prefill(std::slice::from_ref(&prompt.to_vec()),
                                   PrefillLogits::LastToken)?;
        let (pruned, wanda_ffw) = match mode {
            Mode::Full => (None, None),
            Mode::Griffin { keep, strategy } => {
                // shared selection routing: adaptive-layer scores
                // through the ragged executables the serving path
                // uses, so quality sweeps measure the real thing
                let stats = pre.stats[0].clone();
                let (pw, _, _) = self.griffin_weights(
                    pre.state.batch, &stats, keep, strategy)?;
                (Some(pw), None)
            }
            Mode::Magnitude { keep } => {
                let keep = self.bucket_keep(pre.state.batch, keep)?;
                let idx = self.magnitude_experts(keep)?;
                (Some(self.gather_cached(&idx)?), None)
            }
            Mode::Wanda { keep } => {
                let ffw = self.wanda_weights(
                    &pre.xnorms[0], &pre.znorms[0], keep)?;
                (None, Some(ffw))
            }
        };

        // teacher-forced pass: feed continuation[i], score its logits
        // against continuation[i+1]; the first continuation token is
        // scored from the prompt's last logits.
        let v = self.config().vocab_size;
        let mut nll = Vec::with_capacity(continuation.len());
        nll.push(-log_softmax_at(&pre.last_logits[0],
                                 continuation[0] as usize) as f64);
        let b = pre.state.batch;
        let mut cur = vec![0i32; b];
        for i in 0..continuation.len() - 1 {
            cur[0] = continuation[i];
            let logits = self.decode_step(
                &mut pre.state, &cur, pruned.as_deref(),
                wanda_ffw.as_ref())?;
            nll.push(-log_softmax_at(&logits[..v],
                                     continuation[i + 1] as usize) as f64);
        }
        Ok(nll)
    }
}

/// RMS-combine per-sequence norm stacks (Wanda batch aggregation):
/// norms are l2 over tokens, so the batch aggregate is the l2 over the
/// concatenated token axis = sqrt(sum of squares). Public because the
/// continuous-batching scheduler re-aggregates over occupied slots
/// whenever slot membership changes.
pub fn aggregate_norms(per_seq: &[Vec<Vec<f32>>]) -> Vec<Vec<f32>> {
    let l_n = per_seq[0].len();
    let width = per_seq[0][0].len();
    let mut out = vec![vec![0f32; width]; l_n];
    for seq in per_seq {
        for l in 0..l_n {
            for j in 0..width {
                out[l][j] += seq[l][j] * seq[l][j];
            }
        }
    }
    for row in &mut out {
        for v in row {
            *v = v.sqrt();
        }
    }
    out
}

/// Convenience: decode state + engine pair used by integration tests.
pub type EngineRc = Rc<std::cell::RefCell<Engine>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_norms_is_rms() {
        let a = vec![vec![3.0f32, 0.0]];
        let b = vec![vec![4.0f32, 1.0]];
        let agg = aggregate_norms(&[a, b]);
        assert!((agg[0][0] - 5.0).abs() < 1e-6);
        assert!((agg[0][1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn snap_profile_picks_nearest_by_l1_then_tilt() {
        let cands = vec![
            vec![8, 24],
            vec![24, 8],
            vec![8, 8],
            vec![16, 16],
            vec![24, 24],
        ];
        // exact matches snap to themselves
        assert_eq!(snap_profile(&cands, &[16, 16]), Some(vec![16, 16]));
        assert_eq!(snap_profile(&cands, &[8, 24]), Some(vec![8, 24]));
        // (12, 20): L1 ties [8,24] and [16,16] at 8 — the dot-product
        // tiebreak prefers the candidate tilting the same way
        assert_eq!(snap_profile(&cands, &[12, 20]), Some(vec![8, 24]));
        assert_eq!(snap_profile(&cands, &[20, 12]), Some(vec![24, 8]));
        // near-uniform targets degrade to the uniform bucket
        assert_eq!(snap_profile(&cands, &[15, 17]), Some(vec![16, 16]));
        // arity mismatches are filtered; empty candidate set is None
        assert_eq!(snap_profile(&cands, &[16, 16, 16]), None);
        assert_eq!(snap_profile(&[], &[16, 16]), None);
    }

    #[test]
    fn snap_profile_is_deterministic_on_full_ties() {
        // two candidates equidistant AND equal dot product: the
        // lexicographically smaller one wins, independent of input order
        let a = vec![vec![8, 24], vec![24, 8]];
        let b = vec![vec![24, 8], vec![8, 24]];
        assert_eq!(snap_profile(&a, &[16, 16]), snap_profile(&b, &[16, 16]));
        assert_eq!(snap_profile(&a, &[16, 16]), Some(vec![8, 24]));
    }

    #[test]
    fn profile_frag_matches_emitter_naming() {
        assert_eq!(profile_frag(&[8, 24]), "8x24");
        assert_eq!(profile_frag(&[24, 128, 128, 224]), "24x128x128x224");
    }

    #[test]
    fn adaptive_bucket_is_pinned_to_headline() {
        // pins the behavior of the old `keep.min(0.5).max(0.5)` — a
        // confusing no-op clamp that always evaluated to 0.5 — which
        // adaptive_bucket_keep replaces explicitly
        for keep in [0.0, 0.1, 0.25, 0.5, 0.75, 1.0] {
            let legacy = keep.min(0.5).max(0.5);
            assert_eq!(adaptive_bucket_keep(keep), legacy);
            assert_eq!(adaptive_bucket_keep(keep), ADAPTIVE_HEADLINE_KEEP);
        }
    }
}
