//! The serving engine: ties runtime + selection + sampling into the
//! prompt-phase / generation-phase flow of the paper (Fig. 3).
//!
//!   prompt  →  prefill executable (full model, emits s per FF block)
//!   select  →  host-side strategy over s (GRIFFIN §4.2 / baselines)
//!   gather  →  gather_k executable builds Ŵ_g, Ŵ_1, Ŵ_2 on device
//!   generate→  decode_pruned steps (or full decode / masked-weight decode
//!              for the baselines), KV-cache device-resident throughout.
//!
//! Everything here is single-threaded by design: `PjRtBuffer` is not
//! `Send`, so the engine owns all device state and the server hands it
//! work through channels (server/).

use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::coordinator::selection::{self, LayerStats, Strategy};
use crate::coordinator::sequence::{FinishReason, GenRequest};
use crate::metrics::{MetricsRegistry, Timer};
use crate::runtime::{DeviceTensor, Session, WeightStore};
use crate::sampling::{log_softmax_at, Sampler};
use crate::tensorfile::TensorMap;
use crate::tokenizer::{Tokenizer, EOS_ID, PAD_ID};

/// How the generation phase runs (paper §5.1 comparison set).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Mode {
    /// original model (upper baseline)
    Full,
    /// the paper's method: prompt-prompted expert selection
    Griffin { keep: f64, strategy: Strategy },
    /// static neuron pruning by weight magnitude (structured baseline)
    Magnitude { keep: f64 },
    /// Adaptive Wanda: unstructured masking from prompt activations
    Wanda { keep: f64 },
}

impl Mode {
    pub fn griffin(keep: f64) -> Mode {
        Mode::Griffin { keep, strategy: Strategy::TopK }
    }
    pub fn label(&self) -> String {
        match self {
            Mode::Full => "full".into(),
            Mode::Griffin { keep, strategy } => match strategy {
                Strategy::TopK => format!("griffin@{keep}"),
                Strategy::Sampling { .. } => format!("sampling@{keep}"),
                Strategy::TopKPlusSampling { .. } => {
                    format!("topk+sampling@{keep}")
                }
            },
            Mode::Magnitude { keep } => format!("magnitude@{keep}"),
            Mode::Wanda { keep } => format!("wanda@{keep}"),
        }
    }
}

/// Device-resident pruned FF weights for one expert set.
pub struct PrunedWeights {
    /// in manifest pruned_param_order (w1p, w2p[, wgp])
    pub tensors: Vec<DeviceTensor>,
    pub k: usize,
}

/// Device-resident per-batch decode state.
pub struct DecodeState {
    pub kcache: DeviceTensor,
    pub vcache: DeviceTensor,
    /// per-slot next write position (== tokens seen so far)
    pub pos: Vec<i32>,
    pub batch: usize,
}

/// Host-side results of the prompt phase.
pub struct PrefillOut {
    pub state: DecodeState,
    /// per-sequence, per-layer GRIFFIN statistic s
    pub stats: Vec<LayerStats>,
    /// per-sequence, per-layer FF input column norms (Wanda W1/Wg scores)
    pub xnorms: Vec<LayerStats>,
    /// per-sequence, per-layer raw-activation column norms (Wanda W2)
    pub znorms: Vec<LayerStats>,
    /// logits at each sequence's last real prompt token
    pub last_logits: Vec<Vec<f32>>,
    /// full prompt logits [B][S][V] (kept only when score_prompt)
    pub prompt_logits: Option<Vec<f32>>,
    pub bucket_seq: usize,
    pub lengths: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub text: String,
    pub logprobs: Vec<f32>,
    pub finish: FinishReason,
    pub k_used: Option<usize>,
    pub prefill_ms: f64,
    pub select_ms: f64,
    pub decode_ms: f64,
    /// time-to-first-token (admission → first emitted token)
    pub ttft_ms: f64,
    pub tokens_per_sec: f64,
}

pub struct Engine {
    pub session: Session,
    pub weights: WeightStore,
    /// host copy (magnitude / wanda baselines need raw weight values)
    pub host_weights: TensorMap,
    pub tokenizer: Tokenizer,
    pub metrics: Arc<MetricsRegistry>,
    magnitude_cache: Option<Vec<Vec<i32>>>, // per keep-k gather idx cache
    magnitude_keep: f64,
}

impl Engine {
    pub fn load(artifact_dir: &Path, trained: bool) -> Result<Engine> {
        let session = Session::load(artifact_dir)?;
        let weights = WeightStore::load(&session, trained)?;
        let host_weights =
            crate::tensorfile::read(session.manifest.weights_path(trained)?)?;
        Ok(Engine {
            session,
            weights,
            host_weights,
            tokenizer: Tokenizer::new(),
            metrics: Arc::new(MetricsRegistry::default()),
            magnitude_cache: None,
            magnitude_keep: -1.0,
        })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.session.manifest.config
    }

    // ------------------------------------------------------------------
    // prompt phase
    // ------------------------------------------------------------------

    /// Run the prompt phase for a batch of prompts (padded to buckets).
    pub fn prefill(&self, prompts: &[Vec<i32>], score_prompt: bool)
                   -> Result<PrefillOut> {
        let t = Timer::start();
        let cfg = self.config();
        let n = prompts.len();
        let batch = self
            .session
            .manifest
            .batch_bucket(n)
            .with_context(|| format!("no batch bucket >= {n}"))?;
        let longest = prompts.iter().map(Vec::len).max().unwrap_or(1).max(1);
        // over-long prompts are clamped to the largest compiled bucket
        // (tokenizer::fit keeps the suffix — most recent context)
        let exe = match self.session.manifest.prefill_bucket(batch, longest)
        {
            Some(e) => e.name.clone(),
            None => self
                .session
                .manifest
                .executables
                .values()
                .filter(|e| e.kind == "prefill" && e.batch == Some(batch))
                .max_by_key(|e| e.seq.unwrap_or(0))
                .with_context(|| {
                    format!("no prefill executable for batch={batch}")
                })?
                .name
                .clone(),
        };
        let bucket_seq = self.session.manifest.executables[&exe]
            .seq
            .unwrap();

        // pad the token matrix: real sequences then dummy rows
        let mut tokens = Vec::with_capacity(batch * bucket_seq);
        let mut lengths = Vec::with_capacity(batch);
        for i in 0..batch {
            let ids: &[i32] = if i < n { &prompts[i] } else { &[] };
            let (row, real) = self.tokenizer.fit(ids, bucket_seq);
            // empty dummy rows still need length >= 1 for valid attention
            lengths.push(real.max(1));
            tokens.extend(if real == 0 {
                vec![PAD_ID; bucket_seq]
            } else {
                row
            });
        }
        let toks_dev = self
            .session
            .upload_i32(&[batch, bucket_seq], &tokens)?;
        let lens_i32: Vec<i32> = lengths.iter().map(|&l| l as i32).collect();
        let lens_dev = self.session.upload_i32(&[batch], &lens_i32)?;

        let mut args: Vec<&DeviceTensor> = self.weights.ordered();
        args.push(&toks_dev);
        args.push(&lens_dev);
        let mut outs = self.session.run(&exe, &args)?;
        // outputs: logits, kcache, vcache, stats, xnorms, znorms
        let znorms_t = outs.pop().unwrap();
        let xnorms_t = outs.pop().unwrap();
        let stats_t = outs.pop().unwrap();
        let vcache = outs.pop().unwrap();
        let kcache = outs.pop().unwrap();
        let logits_t = outs.pop().unwrap();

        let v = cfg.vocab_size;
        let logits = logits_t.to_f32()?;
        let last_logits: Vec<Vec<f32>> = (0..n)
            .map(|i| {
                let row = (i * bucket_seq + (lengths[i] - 1)) * v;
                logits[row..row + v].to_vec()
            })
            .collect();

        let split = |t: &DeviceTensor, width: usize| -> Result<Vec<LayerStats>> {
            // [L, B, width] -> per-seq [L][width]
            let host = t.to_f32()?;
            let l_count = cfg.n_layers;
            Ok((0..n)
                .map(|i| {
                    (0..l_count)
                        .map(|l| {
                            let base = (l * batch + i) * width;
                            host[base..base + width].to_vec()
                        })
                        .collect()
                })
                .collect())
        };
        let stats = split(&stats_t, cfg.d_ff)?;
        let xnorms = split(&xnorms_t, cfg.d_model)?;
        let znorms = split(&znorms_t, cfg.d_ff)?;

        self.metrics.prompt_tokens.add(
            lengths.iter().take(n).sum::<usize>() as u64);
        t.record_into(&self.metrics.prefill_latency);

        Ok(PrefillOut {
            state: DecodeState {
                kcache,
                vcache,
                pos: lens_i32,
                batch,
            },
            stats,
            xnorms,
            znorms,
            last_logits,
            prompt_logits: if score_prompt { Some(logits) } else { None },
            bucket_seq,
            lengths,
        })
    }

    // ------------------------------------------------------------------
    // expert selection + gather
    // ------------------------------------------------------------------

    /// Round a keep fraction to the nearest compiled k bucket.
    pub fn k_for(&self, keep: f64) -> Result<usize> {
        self.session
            .manifest
            .nearest_k(keep)
            .context("config has no keep_ks")
    }

    /// Build device-resident pruned FF weights for an expert index set.
    pub fn gather(&self, idx: &[Vec<i32>]) -> Result<PrunedWeights> {
        let t = Timer::start();
        let cfg = self.config();
        let k = idx[0].len();
        if idx.len() != cfg.n_layers || idx.iter().any(|l| l.len() != k) {
            bail!("gather: idx must be [L][k]");
        }
        let name = format!("gather_k{k}");
        if !self.session.manifest.executables.contains_key(&name) {
            bail!("no gather executable for k={k} \
                   (available: {:?})", cfg.keep_ks);
        }
        let flat: Vec<i32> = idx.iter().flatten().copied().collect();
        let idx_dev = self.session.upload_i32(&[cfg.n_layers, k], &flat)?;
        // ff params in the order aot emitted them: w1, w2 [, wg]
        let mut args: Vec<&DeviceTensor> = vec![
            self.weights.get("w1"),
            self.weights.get("w2"),
        ];
        if cfg.is_glu {
            args.push(self.weights.get("wg"));
        }
        args.push(&idx_dev);
        let outs = self.session.run(&name, &args)?;
        t.record_into(&self.metrics.gather_latency);
        Ok(PrunedWeights { tensors: outs, k })
    }

    /// Layer-adaptive gather (extension; DESIGN.md §6): per-layer budgets
    /// under a global average keep fraction, padded slots masked to zero.
    pub fn gather_adaptive(&self, stats: &LayerStats, keep: f64)
                           -> Result<PrunedWeights> {
        let t = Timer::start();
        let cfg = self.config();
        let k_bucket = self.k_for(keep.min(0.5).max(0.5))?; // masked gather
        // is emitted at the headline (50%) bucket only
        let k_avg = ((cfg.d_ff as f64 * keep).round() as usize)
            .min(k_bucket);
        let (idx, mask) = selection::adaptive_layer_allocation(
            stats, k_avg, k_bucket);
        let name = format!("gather_masked_k{k_bucket}");
        if !self.session.manifest.executables.contains_key(&name) {
            bail!("no {name} artifact (re-run make artifacts)");
        }
        let flat_idx: Vec<i32> = idx.iter().flatten().copied().collect();
        let flat_mask: Vec<f32> = mask.iter().flatten().copied().collect();
        let idx_dev = self
            .session
            .upload_i32(&[cfg.n_layers, k_bucket], &flat_idx)?;
        let mask_dev = self
            .session
            .upload_f32(&[cfg.n_layers, k_bucket], &flat_mask)?;
        let mut args: Vec<&DeviceTensor> =
            vec![self.weights.get("w1"), self.weights.get("w2")];
        if cfg.is_glu {
            args.push(self.weights.get("wg"));
        }
        args.push(&idx_dev);
        args.push(&mask_dev);
        let outs = self.session.run(&name, &args)?;
        t.record_into(&self.metrics.gather_latency);
        Ok(PrunedWeights { tensors: outs, k: k_bucket })
    }

    /// GRIFFIN selection for one sequence (paper §4.2) or any stats set.
    pub fn select(&self, stats: &LayerStats, keep: f64, strategy: Strategy)
                  -> Result<Vec<Vec<i32>>> {
        let t = Timer::start();
        let k = self.k_for(keep)?;
        let idx = selection::select_experts(stats, k, strategy);
        t.record_into(&self.metrics.selection_latency);
        Ok(idx)
    }

    /// Static magnitude expert set (cached; prompt-independent).
    pub fn magnitude_experts(&mut self, keep: f64) -> Result<Vec<Vec<i32>>> {
        if self.magnitude_keep == keep {
            if let Some(idx) = &self.magnitude_cache {
                return Ok(idx.clone());
            }
        }
        let cfg = self.config().clone();
        let w1 = self.host_weights["w1"].to_f32()?;
        let wg = if cfg.is_glu {
            Some(self.host_weights["wg"].to_f32()?)
        } else {
            None
        };
        let metric = selection::magnitude_metric(
            &w1, wg.as_deref(), cfg.n_layers, cfg.d_ff, cfg.d_model);
        let k = self.k_for(keep)?;
        let idx = selection::select_experts(&metric, k, Strategy::TopK);
        self.magnitude_cache = Some(idx.clone());
        self.magnitude_keep = keep;
        Ok(idx)
    }

    /// Adaptive-Wanda masked FF weights for one sequence (uploads
    /// full-size masked copies; unstructured baseline, §5.1).
    pub fn wanda_weights(&self, xnorm: &LayerStats, znorm: &LayerStats,
                         keep: f64) -> Result<Vec<DeviceTensor>> {
        let cfg = self.config();
        let (l_n, f, d) = (cfg.n_layers, cfg.d_ff, cfg.d_model);
        let mask_stack = |w: &mut Vec<f32>, norms: &LayerStats,
                          rows: usize, cols: usize| {
            for l in 0..l_n {
                selection::wanda_mask_rows(
                    &mut w[l * rows * cols..(l + 1) * rows * cols],
                    &norms[l], rows, cols, keep);
            }
        };
        let mut out = Vec::new();
        let mut w1 = self.host_weights["w1"].to_f32()?;
        mask_stack(&mut w1, xnorm, f, d);
        out.push(self.session.upload_f32(&[l_n, f, d], &w1)?);
        let mut w2 = self.host_weights["w2"].to_f32()?;
        mask_stack(&mut w2, znorm, d, f);
        out.push(self.session.upload_f32(&[l_n, d, f], &w2)?);
        if cfg.is_glu {
            let mut wg = self.host_weights["wg"].to_f32()?;
            mask_stack(&mut wg, xnorm, f, d);
            out.push(self.session.upload_f32(&[l_n, f, d], &wg)?);
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    // generation phase
    // ------------------------------------------------------------------

    /// One decode step (low-level; the experiment drivers also use this
    /// directly for fixed-expert ablations). `ff` selects the weight set:
    ///   None -> full model decode_b{B}
    ///   Some(pruned) -> decode_pruned_b{B}_k{K}
    /// `override_ff` (Wanda) replaces the full FF stacks in-place.
    pub fn decode_step(
        &self,
        state: &mut DecodeState,
        tokens: &[i32],
        ff: Option<&PrunedWeights>,
        override_ff: Option<&[DeviceTensor]>,
    ) -> Result<Vec<f32>> {
        let t = Timer::start();
        let b = state.batch;
        let tok_dev = self.session.upload_i32(&[b], tokens)?;
        let pos_dev = self.session.upload_i32(&[b], &state.pos)?;

        let name;
        let mut args: Vec<&DeviceTensor> = Vec::new();
        match ff {
            Some(pruned) => {
                name = format!("decode_pruned_b{b}_k{}", pruned.k);
                args.extend(self.weights.ordered_nonff());
                args.extend(pruned.tensors.iter());
            }
            None => {
                name = format!("decode_b{b}");
                match override_ff {
                    None => args.extend(self.weights.ordered()),
                    Some(ffw) => {
                        // replace w1/w2/wg slots in ABI order
                        for pname in &self.weights.param_order {
                            args.push(match pname.as_str() {
                                "w1" => &ffw[0],
                                "w2" => &ffw[1],
                                "wg" => &ffw[2],
                                _ => self.weights.get(pname),
                            });
                        }
                    }
                }
            }
        }
        args.push(&state.kcache);
        args.push(&state.vcache);
        args.push(&tok_dev);
        args.push(&pos_dev);

        let mut outs = self.session.run(&name, &args)?;
        let vcache = outs.pop().unwrap();
        let kcache = outs.pop().unwrap();
        let logits = outs.pop().unwrap().to_f32()?;
        state.kcache = kcache;
        state.vcache = vcache;
        for p in state.pos.iter_mut() {
            *p += 1;
        }
        t.record_into(&self.metrics.decode_step_latency);
        Ok(logits)
    }

    // ------------------------------------------------------------------
    // slot-state management (continuous batching; scheduler.rs)
    // ------------------------------------------------------------------

    /// KV-cache shape of the compiled decode executable for `batch`
    /// ([L, B, H, Smax, dh] — aot.py cache_spec).
    pub fn decode_cache_shape(&self, batch: usize) -> Result<Vec<usize>> {
        let name = format!("decode_b{batch}");
        let spec = self
            .session
            .manifest
            .executables
            .get(&name)
            .with_context(|| format!("no decode executable for b={batch}"))?;
        spec.inputs
            .iter()
            .find(|io| io.name == "kcache")
            .map(|io| io.shape.clone())
            .with_context(|| format!("{name}: no kcache input"))
    }

    /// Allocate an empty persistent decode state for a slot pool of
    /// `batch` slots (zeroed KV cache, all positions 0).
    pub fn new_decode_state(&self, batch: usize) -> Result<DecodeState> {
        let shape = self.decode_cache_shape(batch)?;
        let zeros = vec![0f32; shape.iter().product()];
        Ok(DecodeState {
            kcache: self.session.upload_f32(&shape, &zeros)?,
            vcache: self.session.upload_f32(&shape, &zeros)?,
            pos: vec![0; batch],
            batch,
        })
    }

    /// Copy freshly prefilled sequences into slots of a persistent decode
    /// state: for each `(src_row, dst_slot)` pair the whole KV row
    /// [L, :, H, Smax, dh] and the write position move from `src` to
    /// `dst`. Host-staged (PJRT CPU exposes no device-side slice update
    /// across differently-batched executables); fine at our model sizes —
    /// admission is already dominated by the prefill itself.
    pub fn splice_slots(&self, dst: &mut DecodeState, src: &DecodeState,
                        pairs: &[(usize, usize)]) -> Result<()> {
        let t = Timer::start();
        let ds = dst.kcache.shape.clone();
        let ss = src.kcache.shape.clone();
        if ds.len() != 5 || ss.len() != 5 {
            bail!("splice_slots: expected [L,B,H,S,dh] caches");
        }
        if ds[0] != ss[0] || ds[2..] != ss[2..] {
            bail!("splice_slots: incompatible cache shapes {ds:?} vs {ss:?}");
        }
        let (layers, db, sb) = (ds[0], ds[1], ss[1]);
        let row: usize = ds[2..].iter().product();
        for &(si, di) in pairs {
            if si >= sb || di >= db {
                bail!("splice_slots: pair ({si},{di}) out of range \
                       (src b={sb}, dst b={db})");
            }
        }
        let mut dk = dst.kcache.to_f32()?;
        let mut dv = dst.vcache.to_f32()?;
        let sk = src.kcache.to_f32()?;
        let sv = src.vcache.to_f32()?;
        for l in 0..layers {
            for &(si, di) in pairs {
                let s0 = (l * sb + si) * row;
                let d0 = (l * db + di) * row;
                dk[d0..d0 + row].copy_from_slice(&sk[s0..s0 + row]);
                dv[d0..d0 + row].copy_from_slice(&sv[s0..s0 + row]);
            }
        }
        dst.kcache = self.session.upload_f32(&ds, &dk)?;
        dst.vcache = self.session.upload_f32(&ds, &dv)?;
        for &(si, di) in pairs {
            dst.pos[di] = src.pos[si];
        }
        t.record_into(&self.metrics.kv_splice_latency);
        Ok(())
    }

    /// Full request: prompt → (select → gather) → generation (paper Fig 3).
    pub fn generate(&mut self, req: &GenRequest) -> Result<GenResponse> {
        let e2e = Timer::start();
        let responses = self.generate_batch(std::slice::from_ref(req))?;
        let mut r = responses.into_iter().next().unwrap();
        r.tokens_per_sec =
            r.tokens.len() as f64 / e2e.elapsed().as_secs_f64();
        Ok(r)
    }

    /// Batched generation. GRIFFIN batches share one expert set via the
    /// eq.7 aggregate (paper §5.3); Full shares nothing; Magnitude is
    /// static; Wanda masks from the aggregate norms. All requests in the
    /// batch must use the same mode.
    pub fn generate_batch(&mut self, reqs: &[GenRequest])
                          -> Result<Vec<GenResponse>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let e2e = Timer::start();
        let mode = reqs[0].mode;
        if reqs.iter().any(|r| r.mode != mode) {
            bail!("generate_batch: mixed modes");
        }
        let cfg = self.config().clone();
        let prompts: Vec<Vec<i32>> =
            reqs.iter().map(|r| r.prompt.clone()).collect();

        let pre_t = Timer::start();
        let mut pre = self.prefill(&prompts, false)?;
        let prefill_ms = pre_t.elapsed().as_secs_f64() * 1e3;

        // --- selection phase ------------------------------------------
        let sel_t = Timer::start();
        let (pruned, wanda_ffw, k_used): (Option<PrunedWeights>,
                                          Option<Vec<DeviceTensor>>,
                                          Option<usize>) = match mode {
            Mode::Full => (None, None, None),
            Mode::Griffin { keep, strategy } => {
                let agg = selection::aggregate_stats(
                    &pre.stats
                        .iter()
                        .cloned()
                        .zip(pre.lengths.iter().copied())
                        .collect::<Vec<_>>(),
                );
                let idx = self.select(&agg, keep, strategy)?;
                let pw = self.gather(&idx)?;
                let k = pw.k;
                (Some(pw), None, Some(k))
            }
            Mode::Magnitude { keep } => {
                let idx = self.magnitude_experts(keep)?;
                let pw = self.gather(&idx)?;
                let k = pw.k;
                (Some(pw), None, Some(k))
            }
            Mode::Wanda { keep } => {
                // aggregate norms across the batch (rms over sequences)
                let agg_x = aggregate_norms(&pre.xnorms);
                let agg_z = aggregate_norms(&pre.znorms);
                (None, Some(self.wanda_weights(&agg_x, &agg_z, keep)?),
                 None)
            }
        };
        let select_ms = sel_t.elapsed().as_secs_f64() * 1e3;

        // --- generation phase -----------------------------------------
        let dec_t = Timer::start();
        let n = reqs.len();
        let b = pre.state.batch;
        let max_new = reqs.iter().map(|r| r.max_new_tokens).max().unwrap();
        let mut samplers: Vec<Sampler> = reqs
            .iter()
            .map(|r| Sampler::new(r.sampler, r.seed))
            .collect();

        // first token comes from the prompt's last logits
        let mut cur: Vec<i32> = vec![PAD_ID; b];
        let mut done = vec![false; b];
        let mut out_tokens: Vec<Vec<i32>> = vec![Vec::new(); n];
        let mut out_lps: Vec<Vec<f32>> = vec![Vec::new(); n];
        let mut finish = vec![FinishReason::Length; n];
        let mut ttft_ms = vec![0f64; n];
        for i in 0..n {
            let t = samplers[i].sample(&pre.last_logits[i]) as i32;
            let lp = log_softmax_at(&pre.last_logits[i], t as usize);
            // first emitted token: TTFT from admission, like the slot
            // scheduler measures it
            ttft_ms[i] =
                reqs[i].admitted_at.elapsed().as_secs_f64() * 1e3;
            cur[i] = t;
            out_tokens[i].push(t);
            out_lps[i].push(lp);
            if reqs[i].stop_at_eos && t == EOS_ID {
                done[i] = true;
                finish[i] = FinishReason::Eos;
            }
        }
        for slot in n..b {
            done[slot] = true; // padding slots never produce output
        }

        let v = cfg.vocab_size;
        for _step in 1..max_new {
            if done.iter().all(|&d| d) {
                break;
            }
            // context-full guard
            for i in 0..n {
                if !done[i]
                    && (pre.state.pos[i] as usize) >= cfg.max_seq
                {
                    done[i] = true;
                    finish[i] = FinishReason::ContextFull;
                }
            }
            if done.iter().all(|&d| d) {
                break;
            }
            let logits = self.decode_step(
                &mut pre.state, &cur, pruned.as_ref(),
                wanda_ffw.as_deref())?;
            for i in 0..n {
                if done[i] || out_tokens[i].len() >= reqs[i].max_new_tokens
                {
                    done[i] = done[i]
                        || out_tokens[i].len() >= reqs[i].max_new_tokens;
                    continue;
                }
                let row = &logits[i * v..(i + 1) * v];
                let t = samplers[i].sample(row) as i32;
                out_lps[i].push(log_softmax_at(row, t as usize));
                out_tokens[i].push(t);
                cur[i] = t;
                if reqs[i].stop_at_eos && t == EOS_ID {
                    done[i] = true;
                    finish[i] = FinishReason::Eos;
                }
            }
        }
        let decode_ms = dec_t.elapsed().as_secs_f64() * 1e3;

        let total_new: usize = out_tokens.iter().map(Vec::len).sum();
        self.metrics.tokens_generated.add(total_new as u64);
        e2e.record_into(&self.metrics.e2e_latency);
        self.metrics.requests_completed.add(n as u64);

        Ok((0..n)
            .map(|i| GenResponse {
                id: reqs[i].id,
                text: self.tokenizer.decode(&out_tokens[i]),
                tokens: std::mem::take(&mut out_tokens[i]),
                logprobs: std::mem::take(&mut out_lps[i]),
                finish: finish[i],
                k_used,
                prefill_ms,
                select_ms,
                decode_ms,
                ttft_ms: ttft_ms[i],
                tokens_per_sec: total_new as f64
                    / (decode_ms / 1e3).max(1e-9),
            })
            .collect())
    }

    /// Fused-scan greedy generation (throughput path): one PJRT call for
    /// the whole generation phase. Only batch=1, greedy, fixed G buckets.
    pub fn generate_scan(&mut self, req: &GenRequest) -> Result<GenResponse> {
        let e2e = Timer::start();
        let cfg = self.config().clone();
        let pre_t = Timer::start();
        let pre = self.prefill(std::slice::from_ref(&req.prompt), false)?;
        let prefill_ms = pre_t.elapsed().as_secs_f64() * 1e3;
        if pre.state.batch != 1 {
            bail!("generate_scan requires batch bucket 1");
        }

        let sel_t = Timer::start();
        let (exe_name, pruned, k_used) = match req.mode {
            Mode::Full => {
                let g = self.scan_bucket("generate_scan", None,
                                         req.max_new_tokens)?;
                (format!("generate_scan_b1_g{g}"), None, None)
            }
            Mode::Griffin { keep, strategy } => {
                let idx = self.select(&pre.stats[0], keep, strategy)?;
                let pw = self.gather(&idx)?;
                let k = pw.k;
                let g = self.scan_bucket("generate_scan_pruned", Some(k),
                                         req.max_new_tokens)?;
                (format!("generate_scan_pruned_b1_k{k}_g{g}"), Some(pw),
                 Some(k))
            }
            _ => bail!("generate_scan supports Full and Griffin modes"),
        };
        let select_ms = sel_t.elapsed().as_secs_f64() * 1e3;

        let dec_t = Timer::start();
        let first = crate::sampling::argmax(&pre.last_logits[0]) as i32;
        let ttft_ms = req.admitted_at.elapsed().as_secs_f64() * 1e3;
        let tok_dev = self.session.upload_i32(&[1], &[first])?;
        let pos_dev = self.session.upload_i32(&[1], &pre.state.pos)?;
        let mut args: Vec<&DeviceTensor> = Vec::new();
        match &pruned {
            Some(pw) => {
                args.extend(self.weights.ordered_nonff());
                args.extend(pw.tensors.iter());
            }
            None => args.extend(self.weights.ordered()),
        }
        args.push(&pre.state.kcache);
        args.push(&pre.state.vcache);
        args.push(&tok_dev);
        args.push(&pos_dev);
        let outs = self.session.run(&exe_name, &args)?;
        let scan_tokens = outs[0].to_i32()?;
        let scan_lps = outs[1].to_f32()?;
        let decode_ms = dec_t.elapsed().as_secs_f64() * 1e3;

        // assemble: first sampled token + scan outputs, truncated at EOS
        let mut tokens = vec![first];
        let mut lps = vec![log_softmax_at(&pre.last_logits[0],
                                          first as usize)];
        let mut finish = FinishReason::Length;
        if req.stop_at_eos && first == EOS_ID {
            finish = FinishReason::Eos;
        } else {
            for (t, lp) in scan_tokens.iter().zip(&scan_lps) {
                if tokens.len() >= req.max_new_tokens {
                    break;
                }
                tokens.push(*t);
                lps.push(*lp);
                if req.stop_at_eos && *t == EOS_ID {
                    finish = FinishReason::Eos;
                    break;
                }
            }
        }
        let _ = cfg;
        self.metrics.tokens_generated.add(tokens.len() as u64);
        e2e.record_into(&self.metrics.e2e_latency);
        self.metrics.requests_completed.inc();
        Ok(GenResponse {
            id: req.id,
            text: self.tokenizer.decode(&tokens),
            tokens,
            logprobs: lps,
            finish,
            k_used,
            prefill_ms,
            select_ms,
            decode_ms,
            ttft_ms,
            tokens_per_sec: 0.0,
        })
    }

    /// Smallest compiled scan bucket with G >= needed-1 (the first token
    /// comes from prefill logits).
    fn scan_bucket(&self, kind: &str, k: Option<usize>, max_new: usize)
                   -> Result<usize> {
        let need = max_new.saturating_sub(1).max(1);
        self.session
            .manifest
            .executables
            .values()
            .filter(|e| {
                e.kind == kind
                    && e.batch == Some(1)
                    && (k.is_none() || e.k == k)
                    && e.gen.map_or(false, |g| g >= need)
            })
            .filter_map(|e| e.gen)
            .min()
            .with_context(|| {
                format!("no {kind} bucket >= {need} (k={k:?})")
            })
    }

    // ------------------------------------------------------------------
    // teacher-forced scoring (perplexity experiments, Figs 4/5)
    // ------------------------------------------------------------------

    /// Score `continuation` under the model given `prompt`, with the
    /// generation-phase weights chosen by `mode` (experts from the prompt,
    /// as in the paper's language-modeling "simulated generation" setup).
    /// Returns per-token negative log-likelihoods of the continuation.
    pub fn score_continuation(&mut self, prompt: &[i32],
                              continuation: &[i32], mode: Mode)
                              -> Result<Vec<f64>> {
        if prompt.is_empty() || continuation.is_empty() {
            bail!("score_continuation: empty input");
        }
        let mut pre =
            self.prefill(std::slice::from_ref(&prompt.to_vec()), false)?;
        let (pruned, wanda_ffw) = match mode {
            Mode::Full => (None, None),
            Mode::Griffin { keep, strategy } => {
                let idx = self.select(&pre.stats[0], keep, strategy)?;
                (Some(self.gather(&idx)?), None)
            }
            Mode::Magnitude { keep } => {
                let idx = self.magnitude_experts(keep)?;
                (Some(self.gather(&idx)?), None)
            }
            Mode::Wanda { keep } => {
                let ffw = self.wanda_weights(
                    &pre.xnorms[0], &pre.znorms[0], keep)?;
                (None, Some(ffw))
            }
        };

        // teacher-forced pass: feed continuation[i], score its logits
        // against continuation[i+1]; the first continuation token is
        // scored from the prompt's last logits.
        let v = self.config().vocab_size;
        let mut nll = Vec::with_capacity(continuation.len());
        nll.push(-log_softmax_at(&pre.last_logits[0],
                                 continuation[0] as usize) as f64);
        let b = pre.state.batch;
        let mut cur = vec![0i32; b];
        for i in 0..continuation.len() - 1 {
            cur[0] = continuation[i];
            let logits = self.decode_step(
                &mut pre.state, &cur, pruned.as_ref(),
                wanda_ffw.as_deref())?;
            nll.push(-log_softmax_at(&logits[..v],
                                     continuation[i + 1] as usize) as f64);
        }
        Ok(nll)
    }
}

/// RMS-combine per-sequence norm stacks (Wanda batch aggregation):
/// norms are l2 over tokens, so the batch aggregate is the l2 over the
/// concatenated token axis = sqrt(sum of squares). Public because the
/// continuous-batching scheduler re-aggregates over occupied slots
/// whenever slot membership changes.
pub fn aggregate_norms(per_seq: &[Vec<Vec<f32>>]) -> Vec<Vec<f32>> {
    let l_n = per_seq[0].len();
    let width = per_seq[0][0].len();
    let mut out = vec![vec![0f32; width]; l_n];
    for seq in per_seq {
        for l in 0..l_n {
            for j in 0..width {
                out[l][j] += seq[l][j] * seq[l][j];
            }
        }
    }
    for row in &mut out {
        for v in row {
            *v = v.sqrt();
        }
    }
    out
}

/// Convenience: decode state + engine pair used by integration tests.
pub type EngineRc = Rc<std::cell::RefCell<Engine>>;

pub fn mode_table() -> BTreeMap<&'static str, Mode> {
    let mut m = BTreeMap::new();
    m.insert("full", Mode::Full);
    m.insert("griffin", Mode::griffin(0.5));
    m.insert("magnitude", Mode::Magnitude { keep: 0.5 });
    m.insert("wanda", Mode::Wanda { keep: 0.5 });
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_norms_is_rms() {
        let a = vec![vec![3.0f32, 0.0]];
        let b = vec![vec![4.0f32, 1.0]];
        let agg = aggregate_norms(&[a, b]);
        assert!((agg[0][0] - 5.0).abs() < 1e-6);
        assert!((agg[0][1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn mode_labels() {
        assert_eq!(Mode::Full.label(), "full");
        assert_eq!(Mode::griffin(0.5).label(), "griffin@0.5");
        assert_eq!(Mode::Wanda { keep: 0.75 }.label(), "wanda@0.75");
    }
}
