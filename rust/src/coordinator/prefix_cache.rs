//! Device-resident prompt-prefix cache (host-side bookkeeping).
//!
//! Serving workloads repeat prompt prefixes constantly — shared system
//! prompts, multi-turn conversations that resend the whole history —
//! and the prompt phase recomputes their KV rows and flocking
//! statistics from scratch every time. This module is the bookkeeping
//! core of prefix reuse: block-aligned prompt prefixes are chain-hashed
//! (FNV-1a per block, each boundary's hash extending the previous —
//! the same family as the session-affinity hash in `shard.rs`), and
//! each cached boundary maps to a payload the scheduler fills with the
//! `Rc`-shared device tensors of a completed chunked prefill — the KV
//! rows plus the RUNNING PRE-SQRT selection-statistic sums, so a hit
//! restores both the attention state and the GRIFFIN/Wanda statistics
//! of the prefix exactly.
//!
//! The cache is generic over the payload and holds no device types
//! itself, so the hashing / refcount / eviction invariants are unit-
//! and property-tested in the dependency-free substrate tier. Policy:
//!
//!   * lookup returns the LONGEST cached boundary that is a strict
//!     prefix of the prompt (tail >= 1 token: the final chunk must
//!     sample the first generated token from the last prompt row);
//!   * a hit verifies exact token equality — the hash only routes, it
//!     never vouches (a collision is a miss, not a wrong splice);
//!   * hits acquire a refcount that the scheduler holds for as long as
//!     the admission/slot uses the entry's tensors; eviction NEVER
//!     removes an entry with live refs (the property test pins this);
//!   * eviction is LRU over unreferenced entries under a byte budget.

use std::collections::BTreeMap;

/// FNV-1a 64-bit offset basis / prime (matches the session hash in
/// `shard.rs` — one hash family across the routing tier).
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Chain-hash every block-aligned prefix of `tokens`: entry `i` of the
/// result is `(prefix_len, hash)` for the prefix of `i + 1` blocks,
/// where each hash extends the previous block's (so the hash of a
/// longer prefix is computable from the shorter one's — the cache and
/// the shard prefix directory agree by construction). Token bytes are
/// hashed little-endian, like the session id in `shard.rs`.
pub fn chain_hashes(tokens: &[i32], block: usize) -> Vec<(usize, u64)> {
    let mut out = Vec::new();
    if block == 0 {
        return out;
    }
    let mut h = FNV_OFFSET;
    let mut i = 0;
    while i + block <= tokens.len() {
        for &t in &tokens[i..i + block] {
            for b in t.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
        }
        i += block;
        out.push((i, h));
    }
    out
}

/// Hash of the first block only — the shard router's prefix-directory
/// key (requests sharing a system prompt share it).
pub fn first_block_hash(tokens: &[i32], block: usize) -> Option<u64> {
    chain_hashes(&tokens[..tokens.len().min(block)], block)
        .first()
        .map(|&(_, h)| h)
}

/// One cached block-aligned prefix.
struct PrefixEntry<T> {
    /// exact prefix tokens — hash collisions verify against these
    tokens: Vec<i32>,
    payload: T,
    bytes: u64,
    /// live uses (in-flight chunked admissions + occupied slots whose
    /// state was seeded from this entry); eviction skips refs > 0
    refs: u32,
    last_used: u64,
    hits: u64,
}

/// Identity of a cache entry, held by whoever acquired a ref (slot
/// entries record it so retirement can release).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixKey {
    pub prefix_len: usize,
    pub hash: u64,
}

/// A successful lookup: the entry's key plus a borrow of its payload.
pub struct PrefixHit<'a, T> {
    pub key: PrefixKey,
    pub payload: &'a T,
}

/// Ref-counted, byte-budgeted LRU cache of block-aligned prompt
/// prefixes. See the module docs for the policy.
pub struct PrefixCache<T> {
    block: usize,
    budget_bytes: u64,
    entries: BTreeMap<(usize, u64), PrefixEntry<T>>,
    bytes: u64,
    tick: u64,
    evictions: u64,
}

impl<T> PrefixCache<T> {
    pub fn new(block: usize, budget_bytes: u64) -> Self {
        PrefixCache {
            block,
            budget_bytes,
            entries: BTreeMap::new(),
            bytes: 0,
            tick: 0,
            evictions: 0,
        }
    }

    /// Block granule (the engine's smallest positioned prefill bucket).
    pub fn block(&self) -> usize {
        self.block
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Resident payload bytes (as declared at insert).
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Live-ref count of an entry (test/metrics introspection).
    pub fn refs(&self, key: PrefixKey) -> Option<u32> {
        self.entries
            .get(&(key.prefix_len, key.hash))
            .map(|e| e.refs)
    }

    pub fn contains(&self, key: PrefixKey) -> bool {
        self.entries.contains_key(&(key.prefix_len, key.hash))
    }

    /// Longest cached strict prefix of `tokens` (tail >= 1 token so the
    /// final chunk still has a row to sample from). A hit ACQUIRES a
    /// ref — the caller must pair it with [`PrefixCache::release`] when
    /// the admission or the slot seeded from it retires.
    pub fn acquire(&mut self, tokens: &[i32]) -> Option<PrefixHit<'_, T>> {
        let bounds = chain_hashes(tokens, self.block);
        for &(plen, hash) in bounds.iter().rev() {
            if plen >= tokens.len() {
                continue; // need a non-empty tail
            }
            let Some(e) = self.entries.get_mut(&(plen, hash)) else {
                continue;
            };
            // the hash routes; exact tokens vouch (collision = miss)
            if e.tokens[..] != tokens[..plen] {
                continue;
            }
            self.tick += 1;
            e.last_used = self.tick;
            e.refs += 1;
            e.hits += 1;
            return Some(PrefixHit {
                key: PrefixKey { prefix_len: plen, hash },
                payload: &e.payload,
            });
        }
        None
    }

    /// Acquire a ref on a KNOWN entry without the lookup bookkeeping
    /// (no hit count, no LRU touch): the cold admission path retains
    /// the snapshot it just inserted so its own slot's lifetime pins
    /// the entry, exactly like a warm hit's ref does. False if the key
    /// is not resident (the insert was rejected).
    pub fn retain(&mut self, key: PrefixKey) -> bool {
        match self.entries.get_mut(&(key.prefix_len, key.hash)) {
            Some(e) => {
                e.refs += 1;
                true
            }
            None => false,
        }
    }

    /// Drop one live ref. Unknown keys are ignored (the entry may have
    /// been cleared administratively; refs never go negative).
    pub fn release(&mut self, key: PrefixKey) {
        if let Some(e) = self.entries.get_mut(&(key.prefix_len, key.hash))
        {
            e.refs = e.refs.saturating_sub(1);
        }
    }

    /// Insert a block-aligned prefix snapshot. No-op (false) when the
    /// boundary is already cached or when the entry cannot fit the byte
    /// budget even after evicting every unreferenced entry. New entries
    /// start unreferenced — a later hit acquires.
    pub fn insert(&mut self, key: PrefixKey, tokens: Vec<i32>, payload: T,
                  bytes: u64) -> bool {
        debug_assert_eq!(tokens.len(), key.prefix_len);
        if key.prefix_len == 0
            || key.prefix_len % self.block != 0
            || tokens.len() != key.prefix_len
        {
            return false;
        }
        if self.entries.contains_key(&(key.prefix_len, key.hash)) {
            return false;
        }
        if !self.make_room(bytes) {
            return false;
        }
        self.tick += 1;
        self.bytes += bytes;
        self.entries.insert(
            (key.prefix_len, key.hash),
            PrefixEntry {
                tokens,
                payload,
                bytes,
                refs: 0,
                last_used: self.tick,
                hits: 0,
            },
        );
        true
    }

    /// Evict LRU unreferenced entries until `need` more bytes fit the
    /// budget; false if impossible (live refs pin too much).
    fn make_room(&mut self, need: u64) -> bool {
        if need > self.budget_bytes {
            return false;
        }
        while self.bytes + need > self.budget_bytes {
            let victim = self
                .entries
                .iter()
                .filter(|(_, e)| e.refs == 0)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k);
            match victim {
                Some(k) => {
                    let e = self.entries.remove(&k).unwrap();
                    self.bytes -= e.bytes;
                    self.evictions += 1;
                }
                None => return false,
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::rng::XorShift64Star;

    const B: usize = 16;

    fn toks(n: usize, seed: i32) -> Vec<i32> {
        (0..n as i32).map(|i| (i * 37 + seed) % 251).collect()
    }

    #[test]
    fn chain_hashes_extend_and_only_cover_full_blocks() {
        let t = toks(40, 1);
        let h = chain_hashes(&t, B);
        // 40 tokens -> boundaries at 16 and 32 only
        assert_eq!(h.iter().map(|&(l, _)| l).collect::<Vec<_>>(),
                   vec![16, 32]);
        // a longer prompt sharing the prefix produces the SAME chain
        let t2: Vec<i32> =
            t.iter().copied().chain([9, 9, 9, 9, 9, 9, 9, 9]).collect();
        assert_eq!(chain_hashes(&t2[..32], B), h);
        assert_eq!(chain_hashes(&t2, B)[..2], h[..]);
        // diverging in the second block changes that boundary only
        let mut t3 = t.clone();
        t3[20] += 1;
        let h3 = chain_hashes(&t3, B);
        assert_eq!(h3[0], h[0]);
        assert_ne!(h3[1].1, h[1].1);
        assert_eq!(first_block_hash(&t, B), Some(h[0].1));
        assert_eq!(first_block_hash(&t[..8], B), None);
        assert!(chain_hashes(&t, 0).is_empty());
    }

    #[test]
    fn acquire_returns_longest_strict_prefix() {
        let mut c: PrefixCache<&'static str> = PrefixCache::new(B, 1000);
        let t = toks(48, 2);
        let h = chain_hashes(&t, B);
        let k16 = PrefixKey { prefix_len: 16, hash: h[0].1 };
        let k32 = PrefixKey { prefix_len: 32, hash: h[1].1 };
        assert!(c.insert(k16, t[..16].to_vec(), "p16", 10));
        assert!(c.insert(k32, t[..32].to_vec(), "p32", 10));
        // longest wins
        let hit = c.acquire(&t).unwrap();
        assert_eq!(hit.key, k32);
        assert_eq!(*hit.payload, "p32");
        // a 32-token prompt may only use the 16 boundary (tail >= 1)
        let hit = c.acquire(&t[..32]).unwrap();
        assert_eq!(hit.key, k16);
        // 16 tokens: no strict-prefix boundary at all
        assert!(c.acquire(&t[..16]).is_none());
        // unrelated prompt misses
        assert!(c.acquire(&toks(48, 9)).is_none());
        assert_eq!(c.refs(k32), Some(1));
        assert_eq!(c.refs(k16), Some(1));
    }

    #[test]
    fn hash_collision_is_a_miss_not_a_wrong_hit() {
        let mut c: PrefixCache<&'static str> = PrefixCache::new(B, 1000);
        let t = toks(32, 3);
        let h = chain_hashes(&t, B)[0].1;
        // forge an entry whose hash matches `t`'s first block but whose
        // tokens differ — exactly what a real collision would look like
        let key = PrefixKey { prefix_len: 16, hash: h };
        assert!(c.insert(key, toks(16, 7), "forged", 10));
        assert!(c.acquire(&t).is_none(), "collision must verify-miss");
        assert_eq!(c.refs(key), Some(0), "miss acquires nothing");
    }

    #[test]
    fn insert_rejects_unaligned_duplicate_and_oversized() {
        let mut c: PrefixCache<u8> = PrefixCache::new(B, 100);
        let t = toks(16, 4);
        let key = PrefixKey { prefix_len: 16, hash: 1 };
        assert!(!c.insert(PrefixKey { prefix_len: 10, hash: 1 },
                          toks(10, 4), 0, 10),
                "unaligned boundary");
        assert!(!c.insert(PrefixKey { prefix_len: 0, hash: 1 },
                          vec![], 0, 10),
                "empty prefix");
        assert!(!c.insert(key, t[..8].to_vec(), 0, 10),
                "token/len mismatch");
        assert!(c.insert(key, t.clone(), 0, 10));
        assert!(!c.insert(key, t.clone(), 0, 10), "duplicate boundary");
        assert!(!c.insert(PrefixKey { prefix_len: 16, hash: 2 },
                          t.clone(), 0, 101),
                "larger than the whole budget");
        assert_eq!(c.bytes(), 10);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn lru_eviction_under_pressure_skips_live_refs() {
        let mut c: PrefixCache<usize> = PrefixCache::new(B, 30);
        let prompts: Vec<Vec<i32>> =
            (0..3).map(|s| toks(32, 100 + s)).collect();
        let keys: Vec<PrefixKey> = prompts
            .iter()
            .map(|p| PrefixKey {
                prefix_len: 16,
                hash: chain_hashes(p, B)[0].1,
            })
            .collect();
        for (i, p) in prompts.iter().enumerate() {
            assert!(c.insert(keys[i], p[..16].to_vec(), i, 10));
        }
        // pin entry 0 with a live ref; entry 1 is the LRU victim
        assert!(c.acquire(&prompts[0]).is_some());
        let extra = toks(32, 999);
        let ek = PrefixKey {
            prefix_len: 16,
            hash: chain_hashes(&extra, B)[0].1,
        };
        assert!(c.insert(ek, extra[..16].to_vec(), 9, 10));
        assert!(c.contains(keys[0]), "referenced entry survives");
        assert!(!c.contains(keys[1]), "LRU unreferenced entry evicted");
        assert_eq!(c.evictions(), 1);
        // with everything referenced, insertion fails rather than evict
        assert!(c.acquire(&prompts[2]).is_some());
        assert!(c.acquire(&extra).is_some());
        let more = toks(32, 555);
        let mk = PrefixKey {
            prefix_len: 16,
            hash: chain_hashes(&more, B)[0].1,
        };
        assert!(!c.insert(mk, more[..16].to_vec(), 9, 10),
                "all entries ref'd: no room can be made");
        // release unpins: the released entry becomes evictable again
        c.release(keys[2]);
        assert!(c.insert(mk, more[..16].to_vec(), 9, 10));
        assert!(!c.contains(keys[2]));
    }

    /// Property test: a randomized acquire/release/insert storm never
    /// evicts a referenced entry, never over-spends the byte budget,
    /// and keeps byte accounting exact.
    #[test]
    fn randomized_ops_preserve_ref_and_budget_invariants() {
        let mut rng = XorShift64Star::new(7);
        let mut c: PrefixCache<u64> = PrefixCache::new(B, 200);
        // pool of 12 distinct prompts, 48 tokens each (2 boundaries)
        let prompts: Vec<Vec<i32>> =
            (0..12).map(|s| toks(48, s * 17 + 1)).collect();
        let mut held: Vec<(PrefixKey, usize)> = Vec::new(); // (key, owner)
        for step in 0..2000 {
            let p = &prompts[rng.below(prompts.len())];
            match rng.below(4) {
                0 => {
                    if let Some(hit) = c.acquire(p) {
                        held.push((hit.key, step));
                    }
                }
                1 => {
                    if !held.is_empty() {
                        let i = rng.below(held.len());
                        let (k, _) = held.swap_remove(i);
                        c.release(k);
                    }
                }
                _ => {
                    let blocks = 1 + rng.below(2); // 16 or 32
                    let plen = blocks * B;
                    let key = PrefixKey {
                        prefix_len: plen,
                        hash: chain_hashes(&p[..plen], B)[blocks - 1].1,
                    };
                    let bytes = 10 + rng.below(40) as u64;
                    c.insert(key, p[..plen].to_vec(), step as u64, bytes);
                }
            }
            // invariants after every op
            assert!(c.bytes() <= 200, "byte budget exceeded");
            for &(k, _) in &held {
                assert!(c.contains(k),
                        "entry with a live ref was evicted");
            }
            let expect_bytes: u64 = c
                .entries
                .values()
                .map(|e| e.bytes)
                .sum();
            assert_eq!(c.bytes(), expect_bytes, "byte accounting drift");
            for (k, e) in &c.entries {
                let held_refs =
                    held.iter().filter(|(hk, _)| {
                        (hk.prefix_len, hk.hash) == *k
                    }).count() as u32;
                assert_eq!(e.refs, held_refs, "refcount drift");
            }
        }
    }
}
