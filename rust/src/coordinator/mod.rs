//! Layer-3 coordinator: the paper's serving-side contribution.
//!
//! - `selection`  — GRIFFIN expert selection + baselines (§4.2, Tables 4-5)
//! - `sequence`   — request/sequence state machine
//! - `router`     — admission, backpressure
//! - `scheduler`  — wave batching over compiled buckets
//! - `engine`     — prefill/select/gather/decode orchestration over PJRT

pub mod engine;
pub mod router;
pub mod scheduler;
pub mod selection;
pub mod sequence;
