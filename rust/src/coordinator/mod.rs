//! Layer-3 coordinator: the paper's serving-side contribution.
//!
//! - `types`      — runtime-free Mode / GenResponse (substrate builds)
//! - `selection`  — GRIFFIN expert selection + baselines (§4.2, Tables 4-5)
//! - `sequence`   — request/sequence state machine
//! - `prefix_cache` — ref-counted, byte-budgeted LRU of block-aligned
//!   prompt prefixes (chain-hashed); payload-generic so the scheduler
//!   stores device tensors while the invariants test dependency-free
//! - `router`     — admission control, backpressure, cancel flags
//! - `shard`      — sharded admission front: placement (least-loaded +
//!   session affinity), work stealing, per-shard health
//! - `slots`      — slot pool (continuous-batching bookkeeping)
//! - `scheduler`  — continuous batching over the compiled batch buckets
//! - `engine`     — prefill/select/gather/decode orchestration over PJRT
//! - `specdec`    — self-speculative draft→verify→accept core (the
//!   pruned model as a zero-extra-memory drafter; engine-free)
//! - `gather_cache` — LRU reuse of device-resident pruned weight sets
//!
//! `engine` and `scheduler` dispatch through the `runtime::Substrate`
//! trait and are gated behind the internal `engine` cargo feature
//! (enabled by the `runtime` PJRT backend or the `cpu-substrate`
//! reference backend); everything else builds dependency-free (the CI
//! substrate job runs with `--no-default-features`).

#[cfg(feature = "engine")]
pub mod engine;
pub mod gather_cache;
pub mod prefix_cache;
pub mod router;
#[cfg(feature = "engine")]
pub mod scheduler;
pub mod selection;
pub mod sequence;
pub mod specdec;
pub mod shard;
pub mod slots;
pub mod types;
