//! Layer-3 coordinator: the paper's serving-side contribution.
//!
//! - `selection`  — GRIFFIN expert selection + baselines (§4.2, Tables 4-5)
//! - `sequence`   — request/sequence state machine
//! - `router`     — admission control, backpressure, condvar wakeup
//! - `slots`      — slot pool (continuous-batching bookkeeping)
//! - `scheduler`  — continuous batching over the compiled batch buckets
//! - `engine`     — prefill/select/gather/decode orchestration over PJRT
//! - `gather_cache` — LRU reuse of device-resident pruned weight sets

pub mod engine;
pub mod gather_cache;
pub mod router;
pub mod scheduler;
pub mod selection;
pub mod sequence;
pub mod slots;
