//! Slot pool: the bookkeeping core of continuous batching.
//!
//! A fixed pool of decode slots (sized to the largest compiled batch
//! bucket) holds one in-flight sequence per slot. The scheduler admits
//! queued requests into free slots, decodes all occupied slots each tick,
//! and retires slots the moment their sequence finishes — freed slots are
//! back-filled from the queue on the next tick, so a straggler never
//! holds the whole batch hostage.
//!
//! This module is pure host-side state (no PJRT): invariants are
//! property-tested here without artifacts. The pool enforces:
//!   * a slot is never double-assigned,
//!   * every admitted sequence is retired exactly once,
//!   * occupancy accounting (`occupied()`) always matches the slot map.
//!
//! Per-slot GRIFFIN state: each slot keeps the prompt statistics (eq. 6)
//! and the slot-private expert selection computed at admission, and drops
//! both at retirement. The scheduler uses the private selection when a
//! single sequence occupies the pool and falls back to the shared eq. 7
//! aggregate over all occupied slots otherwise (the compiled
//! `decode_pruned` buckets take one pruned weight set per batch).

use std::time::Instant;

use anyhow::{bail, Result};

use crate::coordinator::prefix_cache::PrefixKey;
use crate::coordinator::selection::LayerStats;
use crate::coordinator::types::{CacheInfo, Mode};
use crate::coordinator::sequence::Sequence;
use crate::sampling::{DeviceSampler, Sampler};

/// One occupied decode slot: the sequence plus everything needed to keep
/// sampling it across ticks.
pub struct SlotEntry {
    pub seq: Sequence,
    pub sampler: Sampler,
    /// prompt length as seen by the prefill bucket (for eq. 7 weighting)
    pub prompt_len: usize,
    /// GRIFFIN: per-sequence prompt statistic s (eq. 6)
    pub stats: Option<LayerStats>,
    /// GRIFFIN: slot-private expert selection from `stats`
    pub expert_idx: Option<Vec<Vec<i32>>>,
    /// Wanda: per-sequence FF input / activation column norms
    pub xnorm: Option<LayerStats>,
    pub znorm: Option<LayerStats>,
    /// Host-side mirror of this slot's on-device sampling stream (set at
    /// admission for fused-eligible sampler specs). The mirror is the
    /// SOURCE OF TRUTH for the stream: fused ticks advance it in
    /// lockstep (`skip`), host-fallback ticks sample THROUGH it, and
    /// sampling-state rebuilds upload its state — so a sequence's token
    /// stream is identical no matter how ticks route between the fused
    /// and host paths (seed-reproducibility is routing-independent).
    pub device_mirror: Option<DeviceSampler>,
    /// last token fed to decode (the most recently sampled one)
    pub last_token: i32,
    /// when the previous token was emitted (inter-token latency)
    pub last_token_at: Instant,
    /// wall time of the admission prefill batch this sequence rode in
    pub prefill_ms: f64,
    /// wall time of this sequence's selection at admission
    pub select_ms: f64,
    /// speculative decoding: draft tokens the pruned drafter proposed
    /// for this slot / drafts the full model's verify pass accepted
    /// (response provenance + the per-slot acceptance-rate histogram)
    pub spec_proposed: u64,
    pub spec_accepted: u64,
    /// prefix-cache entry this slot's KV state was seeded from: the
    /// scheduler holds the entry's ref for the slot's whole lifetime
    /// (eviction must never drop tensors a live admission chain used)
    /// and releases it at retirement
    pub cache_ref: Option<PrefixKey>,
    /// prefix-cache provenance threaded into the final response's v2
    /// `cache` object (set by cache-aware chunked admissions)
    pub cache_info: Option<CacheInfo>,
}

impl SlotEntry {
    pub fn new(seq: Sequence, sampler: Sampler, prompt_len: usize) -> Self {
        SlotEntry {
            seq,
            sampler,
            prompt_len,
            stats: None,
            expert_idx: None,
            xnorm: None,
            znorm: None,
            device_mirror: None,
            last_token: 0,
            last_token_at: Instant::now(),
            prefill_ms: 0.0,
            select_ms: 0.0,
            spec_proposed: 0,
            spec_accepted: 0,
            cache_ref: None,
            cache_info: None,
        }
    }

    /// Can this slot ride the fused on-device sampling path? True for
    /// greedy and top-k samplers whose k fits the compiled truncation
    /// bucket (`sample_topk` from the decode_sample manifest entry).
    /// One ineligible slot sends the whole tick to the host-logits path
    /// — the compiled sampler is per-batch, not per-slot.
    pub fn fused_ready(&self, sample_topk: usize) -> bool {
        crate::sampling::fused_eligible(self.sampler.spec, sample_topk)
    }
}

/// Fixed-size pool of decode slots with occupancy invariants.
pub struct SlotPool {
    slots: Vec<Option<SlotEntry>>,
    /// mode of the current continuous run; decode batches must stay
    /// mode-homogeneous because the compiled decode executables bind one
    /// FF weight set per batch
    active_mode: Option<Mode>,
    occupied: usize,
    admitted_total: u64,
    retired_total: u64,
}

impl SlotPool {
    pub fn new(capacity: usize) -> Self {
        SlotPool {
            slots: (0..capacity).map(|_| None).collect(),
            active_mode: None,
            occupied: 0,
            admitted_total: 0,
            retired_total: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn occupied(&self) -> usize {
        self.occupied
    }

    pub fn is_empty(&self) -> bool {
        self.occupied == 0
    }

    pub fn is_full(&self) -> bool {
        self.occupied == self.slots.len()
    }

    /// Mode of the in-flight run. Meaningless (stale) when the pool is
    /// empty — the scheduler adopts the queue head's mode on next admit.
    pub fn active_mode(&self) -> Option<Mode> {
        if self.is_empty() {
            None
        } else {
            self.active_mode
        }
    }

    pub fn set_mode(&mut self, mode: Mode) {
        self.active_mode = Some(mode);
    }

    pub fn free_indices(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
            .collect()
    }

    pub fn occupied_indices(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_some())
            .map(|(i, _)| i)
            .collect()
    }

    pub fn get(&self, slot: usize) -> Option<&SlotEntry> {
        self.slots.get(slot).and_then(Option::as_ref)
    }

    pub fn get_mut(&mut self, slot: usize) -> Option<&mut SlotEntry> {
        self.slots.get_mut(slot).and_then(Option::as_mut)
    }

    /// Slot currently holding request `id`, if any (cancellation lookup).
    pub fn slot_of(&self, id: u64) -> Option<usize> {
        self.slots.iter().position(|s| {
            s.as_ref().is_some_and(|e| e.seq.req.id == id)
        })
    }

    /// Place a sequence into a free slot. Double-assignment is a
    /// scheduling bug and is rejected (never silently overwrites).
    pub fn assign(&mut self, slot: usize, entry: SlotEntry) -> Result<()> {
        if slot >= self.slots.len() {
            bail!("slot {slot} out of range (capacity {})",
                  self.slots.len());
        }
        if self.slots[slot].is_some() {
            bail!(
                "slot {slot} already holds request {}",
                self.slots[slot].as_ref().unwrap().seq.req.id
            );
        }
        self.slots[slot] = Some(entry);
        self.occupied += 1;
        self.admitted_total += 1;
        Ok(())
    }

    /// Free a slot, returning its entry (the scheduler turns it into the
    /// final response). Retiring an empty slot is a scheduling bug.
    pub fn retire(&mut self, slot: usize) -> Result<SlotEntry> {
        if slot >= self.slots.len() {
            bail!("slot {slot} out of range (capacity {})",
                  self.slots.len());
        }
        match self.slots[slot].take() {
            Some(e) => {
                self.occupied -= 1;
                self.retired_total += 1;
                Ok(e)
            }
            None => bail!("retire of unoccupied slot {slot}"),
        }
    }

    pub fn admitted_total(&self) -> u64 {
        self.admitted_total
    }

    pub fn retired_total(&self) -> u64 {
        self.retired_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sequence::{FinishReason, GenRequest, Phase};
    use crate::sampling::SamplerSpec;
    use crate::workload::rng::XorShift64Star;

    fn entry(id: u64) -> SlotEntry {
        let seq =
            Sequence::new(GenRequest::greedy(id, vec![1, 2], 8, Mode::Full));
        SlotEntry::new(seq, Sampler::new(SamplerSpec::Greedy, id), 2)
    }

    #[test]
    fn fused_ready_tracks_sampler_spec() {
        assert!(entry(1).fused_ready(32), "greedy is always eligible");
        let mk = |spec| {
            let seq = Sequence::new(
                GenRequest::greedy(2, vec![1], 8, Mode::Full));
            SlotEntry::new(seq, Sampler::new(spec, 2), 1)
        };
        let topk = mk(SamplerSpec::TopK { k: 64, temperature: 0.9 });
        assert!(!topk.fused_ready(32), "k beyond the compiled bucket");
        assert!(topk.fused_ready(64));
        let topp = mk(SamplerSpec::TopP { p: 0.9, temperature: 1.0 });
        assert!(!topp.fused_ready(64), "nucleus stays on the host path");
    }

    #[test]
    fn assign_and_retire_roundtrip() {
        let mut p = SlotPool::new(4);
        assert_eq!(p.capacity(), 4);
        assert!(p.is_empty());
        p.assign(2, entry(7)).unwrap();
        assert_eq!(p.occupied(), 1);
        assert_eq!(p.free_indices(), vec![0, 1, 3]);
        assert_eq!(p.occupied_indices(), vec![2]);
        assert_eq!(p.get(2).unwrap().seq.req.id, 7);
        assert_eq!(p.slot_of(7), Some(2));
        assert_eq!(p.slot_of(8), None);
        let e = p.retire(2).unwrap();
        assert_eq!(e.seq.req.id, 7);
        assert!(p.is_empty());
    }

    #[test]
    fn double_assign_rejected() {
        let mut p = SlotPool::new(2);
        p.assign(0, entry(1)).unwrap();
        let err = p.assign(0, entry(2)).unwrap_err();
        assert!(err.to_string().contains("already holds"), "{err}");
        // pool state unchanged by the failed assign
        assert_eq!(p.occupied(), 1);
        assert_eq!(p.get(0).unwrap().seq.req.id, 1);
    }

    #[test]
    fn retire_empty_rejected() {
        let mut p = SlotPool::new(2);
        assert!(p.retire(1).is_err());
        assert!(p.assign(5, entry(1)).is_err());
        assert!(p.retire(5).is_err());
    }

    #[test]
    fn active_mode_is_none_when_empty() {
        let mut p = SlotPool::new(2);
        p.set_mode(Mode::griffin(0.5));
        assert_eq!(p.active_mode(), None, "stale mode hidden when empty");
        p.assign(0, entry(1)).unwrap();
        assert_eq!(p.active_mode(), Some(Mode::griffin(0.5)));
        p.retire(0).unwrap();
        assert_eq!(p.active_mode(), None);
    }

    /// Property test: a randomized continuous-batching run where every
    /// sequence has its own length. Every admitted id must retire exactly
    /// once, slots never double-assign, and short sequences must free
    /// their slot (and have it back-filled) while long ones still run.
    #[test]
    fn continuous_run_admits_and_retires_exactly_once() {
        let mut rng = XorShift64Star::new(42);
        let capacity = 4;
        let mut pool = SlotPool::new(capacity);
        // queue of (id, remaining_tokens); lengths vary 1..=12
        let mut queue: std::collections::VecDeque<(u64, usize)> =
            (1..=40u64).map(|id| (id, 1 + rng.below(12))).collect();
        let mut remaining: std::collections::HashMap<usize, usize> =
            std::collections::HashMap::new();
        let mut retired_ids: Vec<u64> = Vec::new();
        let mut max_occupied = 0usize;

        while !(queue.is_empty() && pool.is_empty()) {
            // admission: back-fill every free slot
            for slot in pool.free_indices() {
                let Some((id, len)) = queue.pop_front() else { break };
                let mut e = entry(id);
                e.seq.advance(Phase::Prefilling);
                e.seq.advance(Phase::Decoding);
                e.seq.advance(Phase::Streaming);
                e.seq.slot = Some(slot);
                pool.assign(slot, e).unwrap();
                remaining.insert(slot, len);
            }
            max_occupied = max_occupied.max(pool.occupied());
            // decode tick: every occupied slot produces one token
            for slot in pool.occupied_indices() {
                let left = remaining.get_mut(&slot).unwrap();
                *left -= 1;
                if *left == 0 {
                    remaining.remove(&slot);
                    let mut e = pool.retire(slot).unwrap();
                    e.seq.finish(FinishReason::Length);
                    retired_ids.push(e.seq.req.id);
                }
            }
        }

        retired_ids.sort();
        let expect: Vec<u64> = (1..=40).collect();
        assert_eq!(retired_ids, expect,
                   "every admitted sequence retires exactly once");
        assert_eq!(pool.admitted_total(), 40);
        assert_eq!(pool.retired_total(), 40);
        assert_eq!(max_occupied, capacity,
                   "back-fill keeps the pool saturated");
    }

    /// A short and a long sequence share the pool: the short one finishes
    /// early and its slot is reused by a queued request while the long
    /// one is still streaming — the defining behavior of continuous
    /// batching (the wave scheduler would have blocked on the straggler).
    #[test]
    fn short_sequence_frees_slot_before_straggler_finishes() {
        let mut pool = SlotPool::new(2);
        pool.assign(0, entry(1)).unwrap(); // short: 2 tokens
        pool.assign(1, entry(2)).unwrap(); // long: 10 tokens
        let mut lens = vec![(0usize, 2usize), (1, 10)];
        let mut backfilled_at_tick = None;
        let mut long_alive_at_backfill = false;
        for tick in 0..10 {
            let mut done = Vec::new();
            for (slot, left) in lens.iter_mut() {
                *left -= 1;
                if *left == 0 {
                    done.push(*slot);
                }
            }
            for slot in done {
                pool.retire(slot).unwrap();
                lens.retain(|(s, _)| *s != slot);
                if backfilled_at_tick.is_none() {
                    // back-fill from the "queue" immediately
                    pool.assign(slot, entry(3)).unwrap();
                    lens.push((slot, 3));
                    backfilled_at_tick = Some(tick);
                    long_alive_at_backfill = pool.get(1).is_some();
                }
            }
            if pool.is_empty() {
                break;
            }
        }
        assert_eq!(backfilled_at_tick, Some(1),
                   "short sequence retires at its own length");
        assert!(long_alive_at_backfill,
                "straggler keeps decoding while the freed slot is reused");
        assert_eq!(pool.retired_total(), 3);
    }
}
