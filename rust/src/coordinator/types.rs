//! Runtime-free coordinator types: the generation mode, the response
//! shape, and the mode table.
//!
//! Split out of `engine.rs` so the substrate layers (router, slot pool,
//! sequence state machine, the typed `api` protocol) compile and
//! unit-test without the PJRT runtime — `engine`/`scheduler` re-export
//! these under their old paths, so runtime-enabled code is unaffected.

use crate::coordinator::selection::Strategy;
use crate::coordinator::sequence::FinishReason;

/// How the generation phase runs (paper §5.1 comparison set).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Mode {
    /// original model (upper baseline)
    Full,
    /// the paper's method: prompt-prompted expert selection
    Griffin { keep: f64, strategy: Strategy },
    /// static neuron pruning by weight magnitude (structured baseline)
    Magnitude { keep: f64 },
    /// Adaptive Wanda: unstructured masking from prompt activations
    Wanda { keep: f64 },
}

impl Mode {
    pub fn griffin(keep: f64) -> Mode {
        Mode::Griffin { keep, strategy: Strategy::TopK }
    }

    /// Batching compatibility: requests can share a continuous run when
    /// they decode through the same executable family and weight-set
    /// shape. Strategy seeds (`Strategy::Sampling`/`TopKPlusSampling`)
    /// are per-request selection inputs — the batch-shared eq.7
    /// aggregate uses the run head's seed — so they must NOT fragment
    /// batches (full `==` would serialize seeded-sampling traffic into
    /// batches of one).
    pub fn compatible(&self, other: &Mode) -> bool {
        match (self, other) {
            (
                Mode::Griffin { keep: a, strategy: sa },
                Mode::Griffin { keep: b, strategy: sb },
            ) => {
                a == b
                    && std::mem::discriminant(sa)
                        == std::mem::discriminant(sb)
            }
            _ => self == other,
        }
    }

    pub fn label(&self) -> String {
        match self {
            Mode::Full => "full".into(),
            Mode::Griffin { keep, strategy } => match strategy {
                Strategy::TopK => format!("griffin@{keep}"),
                Strategy::Sampling { .. } => format!("sampling@{keep}"),
                Strategy::TopKPlusSampling { .. } => {
                    format!("topk+sampling@{keep}")
                }
                Strategy::AdaptiveLayer => {
                    format!("adaptive-layer@{keep}")
                }
            },
            Mode::Magnitude { keep } => format!("magnitude@{keep}"),
            Mode::Wanda { keep } => format!("wanda@{keep}"),
        }
    }
}

/// Selection provenance for reproducibility audits (surfaced as the v2
/// response `prune` object): which pruning method/strategy produced the
/// served expert set, and — for stochastic strategies — the seed that
/// drove it, so an audit can re-derive the selection from the same
/// prompt statistics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SelectionInfo {
    pub method: &'static str,
    /// GRIFFIN selection strategy label; None for non-GRIFFIN methods
    pub strategy: Option<&'static str>,
    /// strategy seed (stochastic strategies only)
    pub seed: Option<u64>,
    /// the keep fraction the CLIENT asked for, when the SLO-aware
    /// admission controller down-kept the request under overload
    /// pressure (None when the request was served at its requested
    /// keep). Surfaced in the v2 `prune` object as `keep_requested` +
    /// `degraded:true` so graceful degradation is auditable per
    /// response.
    pub keep_requested: Option<f64>,
}

impl SelectionInfo {
    /// Provenance of a generation mode; None for the full model (no
    /// selection happened, nothing to audit).
    pub fn from_mode(mode: &Mode) -> Option<SelectionInfo> {
        match mode {
            Mode::Full => None,
            Mode::Griffin { strategy, .. } => Some(SelectionInfo {
                method: "griffin",
                strategy: Some(match strategy {
                    Strategy::TopK => "topk",
                    Strategy::Sampling { .. } => "sampling",
                    Strategy::TopKPlusSampling { .. } => "topk+sampling",
                    Strategy::AdaptiveLayer => "adaptive-layer",
                }),
                seed: match strategy {
                    Strategy::TopK | Strategy::AdaptiveLayer => None,
                    Strategy::Sampling { seed }
                    | Strategy::TopKPlusSampling { seed } => Some(*seed),
                },
                keep_requested: None,
            }),
            Mode::Magnitude { .. } => Some(SelectionInfo {
                method: "magnitude",
                strategy: None,
                seed: None,
                keep_requested: None,
            }),
            Mode::Wanda { .. } => Some(SelectionInfo {
                method: "wanda",
                strategy: None,
                seed: None,
                keep_requested: None,
            }),
        }
    }

    /// Stamp the client's original keep onto the provenance (the request
    /// was down-kept at admission; `keep` is what the client asked for).
    pub fn with_requested_keep(mut self, keep: Option<f64>)
                               -> SelectionInfo {
        self.keep_requested = keep;
        self
    }
}

/// Speculative-decoding provenance (surfaced as the v2 response
/// `speculative` object): what the request opted into and how the
/// pruned drafter performed. `accepted / proposed` is the serving-time
/// measurement of the paper's flocking claim — how often the pruned
/// FF block's next-token decision matches the full model's.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpecInfo {
    /// requested draft length (the served length snaps per tick to a
    /// compiled verify bucket and may be smaller)
    pub draft_tokens: usize,
    /// draft tokens the pruned drafter proposed for this sequence
    pub proposed: u64,
    /// drafts the full model's verify pass accepted
    pub accepted: u64,
}

/// Prefix-cache provenance (surfaced as the v2 response `cache`
/// object): how much of the prompt the admission restored from the
/// device-resident prefix cache instead of prefilling. Present exactly
/// when the request was admitted through the cache-aware chunked path
/// (`hit: false` = cold, the prefix was computed and published);
/// `None` when the request never consulted the cache.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheInfo {
    /// prompt tokens restored from a cached prefix (0 on a miss)
    pub prefix_tokens: usize,
    /// whether admission hit the cache
    pub hit: bool,
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub text: String,
    pub logprobs: Vec<f32>,
    pub finish: FinishReason,
    pub k_used: Option<usize>,
    /// adaptive-layer provenance: the exact per-layer FF widths the
    /// response was decoded at (layer order). None for uniform keeps —
    /// `k_used` already tells the whole story there.
    pub k_per_layer: Option<Vec<usize>>,
    /// selection provenance (v2 responses surface it as `prune`)
    pub selection: Option<SelectionInfo>,
    /// speculative-decoding provenance (v2 `speculative` object); None
    /// when the request never opted in
    pub speculative: Option<SpecInfo>,
    /// prefix-cache provenance (v2 `cache` object); None when the
    /// request was admitted outside the cache-aware chunked path
    pub cache: Option<CacheInfo>,
    pub prefill_ms: f64,
    pub select_ms: f64,
    pub decode_ms: f64,
    /// time-to-first-token (admission → first emitted token)
    pub ttft_ms: f64,
    pub tokens_per_sec: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_labels() {
        assert_eq!(Mode::Full.label(), "full");
        assert_eq!(Mode::griffin(0.5).label(), "griffin@0.5");
        assert_eq!(Mode::Wanda { keep: 0.75 }.label(), "wanda@0.75");
        let a = Mode::Griffin {
            keep: 0.5,
            strategy: Strategy::AdaptiveLayer,
        };
        assert_eq!(a.label(), "adaptive-layer@0.5");
    }

    #[test]
    fn selection_provenance_from_mode() {
        assert_eq!(SelectionInfo::from_mode(&Mode::Full), None);
        let g = SelectionInfo::from_mode(&Mode::Griffin {
            keep: 0.5,
            strategy: Strategy::Sampling { seed: 9 },
        })
        .unwrap();
        assert_eq!(g.method, "griffin");
        assert_eq!(g.strategy, Some("sampling"));
        assert_eq!(g.seed, Some(9));
        let t = SelectionInfo::from_mode(&Mode::griffin(0.5)).unwrap();
        assert_eq!(t.strategy, Some("topk"));
        assert_eq!(t.seed, None, "deterministic top-k carries no seed");
        let a = SelectionInfo::from_mode(&Mode::Griffin {
            keep: 0.5,
            strategy: Strategy::AdaptiveLayer,
        })
        .unwrap();
        assert_eq!(a.strategy, Some("adaptive-layer"));
        assert_eq!(a.seed, None, "budget allocation is deterministic");
        let w =
            SelectionInfo::from_mode(&Mode::Wanda { keep: 0.5 }).unwrap();
        assert_eq!((w.method, w.strategy, w.seed), ("wanda", None, None));
        assert_eq!(w.keep_requested, None,
                   "served-as-requested responses carry no degradation");
        let d = w.with_requested_keep(Some(0.75));
        assert_eq!(d.keep_requested, Some(0.75));
    }

    #[test]
    fn seeded_strategies_stay_compatible() {
        let a = Mode::Griffin {
            keep: 0.5,
            strategy: Strategy::Sampling { seed: 1 },
        };
        let b = Mode::Griffin {
            keep: 0.5,
            strategy: Strategy::Sampling { seed: 2 },
        };
        assert!(a.compatible(&b));
        assert!(!a.compatible(&Mode::griffin(0.5)));
        assert!(!a.compatible(&Mode::Full));
    }
}
