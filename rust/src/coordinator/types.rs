//! Runtime-free coordinator types: the generation mode, the response
//! shape, and the mode table.
//!
//! Split out of `engine.rs` so the substrate layers (router, slot pool,
//! sequence state machine, the typed `api` protocol) compile and
//! unit-test without the PJRT runtime — `engine`/`scheduler` re-export
//! these under their old paths, so runtime-enabled code is unaffected.

use crate::coordinator::selection::Strategy;
use crate::coordinator::sequence::FinishReason;

/// How the generation phase runs (paper §5.1 comparison set).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Mode {
    /// original model (upper baseline)
    Full,
    /// the paper's method: prompt-prompted expert selection
    Griffin { keep: f64, strategy: Strategy },
    /// static neuron pruning by weight magnitude (structured baseline)
    Magnitude { keep: f64 },
    /// Adaptive Wanda: unstructured masking from prompt activations
    Wanda { keep: f64 },
}

impl Mode {
    pub fn griffin(keep: f64) -> Mode {
        Mode::Griffin { keep, strategy: Strategy::TopK }
    }

    /// Batching compatibility: requests can share a continuous run when
    /// they decode through the same executable family and weight-set
    /// shape. Strategy seeds (`Strategy::Sampling`/`TopKPlusSampling`)
    /// are per-request selection inputs — the batch-shared eq.7
    /// aggregate uses the run head's seed — so they must NOT fragment
    /// batches (full `==` would serialize seeded-sampling traffic into
    /// batches of one).
    pub fn compatible(&self, other: &Mode) -> bool {
        match (self, other) {
            (
                Mode::Griffin { keep: a, strategy: sa },
                Mode::Griffin { keep: b, strategy: sb },
            ) => {
                a == b
                    && std::mem::discriminant(sa)
                        == std::mem::discriminant(sb)
            }
            _ => self == other,
        }
    }

    pub fn label(&self) -> String {
        match self {
            Mode::Full => "full".into(),
            Mode::Griffin { keep, strategy } => match strategy {
                Strategy::TopK => format!("griffin@{keep}"),
                Strategy::Sampling { .. } => format!("sampling@{keep}"),
                Strategy::TopKPlusSampling { .. } => {
                    format!("topk+sampling@{keep}")
                }
            },
            Mode::Magnitude { keep } => format!("magnitude@{keep}"),
            Mode::Wanda { keep } => format!("wanda@{keep}"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub text: String,
    pub logprobs: Vec<f32>,
    pub finish: FinishReason,
    pub k_used: Option<usize>,
    pub prefill_ms: f64,
    pub select_ms: f64,
    pub decode_ms: f64,
    /// time-to-first-token (admission → first emitted token)
    pub ttft_ms: f64,
    pub tokens_per_sec: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_labels() {
        assert_eq!(Mode::Full.label(), "full");
        assert_eq!(Mode::griffin(0.5).label(), "griffin@0.5");
        assert_eq!(Mode::Wanda { keep: 0.75 }.label(), "wanda@0.75");
    }

    #[test]
    fn seeded_strategies_stay_compatible() {
        let a = Mode::Griffin {
            keep: 0.5,
            strategy: Strategy::Sampling { seed: 1 },
        };
        let b = Mode::Griffin {
            keep: 0.5,
            strategy: Strategy::Sampling { seed: 2 },
        };
        assert!(a.compatible(&b));
        assert!(!a.compatible(&Mode::griffin(0.5)));
        assert!(!a.compatible(&Mode::Full));
    }
}
