//! LRU reuse cache for gathered (pruned) FF weight sets.
//!
//! The continuous-batching scheduler rebuilds its batch-shared pruned
//! weights on every slot-membership change, and many of those rebuilds
//! resolve to an expert selection that is already resident on device:
//! magnitude mode is fully static, a single-slot GRIFFIN pool re-admits
//! the same prompt, and the >1-occupied-slot eq.7 aggregate is stable
//! whenever the surviving slots are unchanged. Re-running `gather_k{K}`
//! for those is pure waste. `Engine::gather_cached` keys device-resident
//! `PrunedWeights` by `(k, fnv1a(expert indices))` and serves repeats
//! from here — hit/miss counts land in `MetricsRegistry::gather_cache_*`.
//!
//! A hit requires BOTH the hash key and an exact index-set compare (the
//! stored selection is the witness): a 64-bit collision must never
//! silently serve another selection's weights. The cache is generic over
//! the stored value so its keying/eviction invariants are unit-testable
//! without PJRT device tensors.

/// Cache key: FF width + 64-bit FNV-1a over the flattened expert index
/// set (layer boundaries included, so [[0,1],[2]] != [[0],[1,2]]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GatherKey {
    pub k: usize,
    pub hash: u64,
}

impl GatherKey {
    pub fn new(idx: &[Vec<i32>]) -> GatherKey {
        let k = idx.first().map_or(0, Vec::len);
        GatherKey { k, hash: idx_hash(idx) }
    }
}

/// FNV-1a over the index set; a layer separator is hashed between rows
/// so per-layer boundaries contribute to the digest.
pub fn idx_hash(idx: &[Vec<i32>]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut mix = |b: u8| {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    };
    for layer in idx {
        for v in layer {
            for b in v.to_le_bytes() {
                mix(b);
            }
        }
        mix(0xff); // layer separator
    }
    h
}

/// Tiny LRU keyed by [`GatherKey`] + exact index-set equality. Capacity
/// is small (a handful of weight sets dominate any steady state) and
/// values are typically `Rc<PrunedWeights>` — evicting here drops the
/// device buffers once the last in-flight user releases its handle.
pub struct GatherCache<T> {
    cap: usize,
    tick: u64,
    entries: Vec<(u64, GatherKey, Vec<Vec<i32>>, T)>,
}

impl<T> GatherCache<T> {
    pub fn new(cap: usize) -> GatherCache<T> {
        GatherCache { cap: cap.max(1), tick: 0, entries: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Look up a selection, refreshing its recency on hit. The hash key
    /// narrows the scan; the stored index set is compared exactly, so a
    /// hash collision is a miss, never a silent wrong-weights hit.
    pub fn get(&mut self, key: &GatherKey, idx: &[Vec<i32>])
               -> Option<&T> {
        self.tick += 1;
        let tick = self.tick;
        self.entries.iter_mut().find_map(|(t, k, stored, v)| {
            if k == key && stored.as_slice() == idx {
                *t = tick;
                Some(&*v)
            } else {
                None
            }
        })
    }

    /// Insert (or refresh) a selection, evicting the least-recently-used
    /// entry when full.
    pub fn insert(&mut self, key: GatherKey, idx: Vec<Vec<i32>>, value: T) {
        self.tick += 1;
        if let Some(slot) = self
            .entries
            .iter_mut()
            .find(|(_, k, stored, _)| *k == key && *stored == idx)
        {
            *slot = (self.tick, key, idx, value);
            return;
        }
        if self.entries.len() >= self.cap {
            let lru = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, (t, _, _, _))| *t)
                .map(|(i, _)| i)
                .unwrap();
            self.entries.swap_remove(lru);
        }
        self.entries.push((self.tick, key, idx, value));
    }

    /// Drop everything (weight reload, tests).
    pub fn clear(&mut self) {
        self.entries.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idx(layers: &[&[i32]]) -> Vec<Vec<i32>> {
        layers.iter().map(|l| l.to_vec()).collect()
    }

    #[test]
    fn key_is_stable_and_selective() {
        let a = idx(&[&[0, 1], &[2, 3]]);
        let b = idx(&[&[0, 1], &[2, 3]]);
        let c = idx(&[&[0, 1], &[2, 4]]);
        assert_eq!(GatherKey::new(&a), GatherKey::new(&b));
        assert_ne!(GatherKey::new(&a), GatherKey::new(&c));
        assert_eq!(GatherKey::new(&a).k, 2);
    }

    #[test]
    fn layer_boundaries_matter() {
        // same flat values, different layer split -> different hash
        let a = idx(&[&[0, 1], &[2]]);
        let b = idx(&[&[0], &[1, 2]]);
        assert_ne!(idx_hash(&a), idx_hash(&b));
    }

    #[test]
    fn hit_refreshes_and_miss_returns_none() {
        let mut c: GatherCache<u32> = GatherCache::new(2);
        let ia = idx(&[&[0, 1]]);
        let ib = idx(&[&[2, 3]]);
        let (ka, kb) = (GatherKey::new(&ia), GatherKey::new(&ib));
        assert!(c.get(&ka, &ia).is_none());
        c.insert(ka, ia.clone(), 10);
        c.insert(kb, ib.clone(), 20);
        assert_eq!(c.get(&ka, &ia), Some(&10));
        assert_eq!(c.get(&kb, &ib), Some(&20));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn hash_collision_is_a_miss_not_a_wrong_hit() {
        // force a "collision" by presenting a forged key whose hash
        // matches entry A but whose index set differs
        let mut c: GatherCache<u32> = GatherCache::new(2);
        let ia = idx(&[&[0, 1]]);
        let ka = GatherKey::new(&ia);
        c.insert(ka, ia.clone(), 10);
        let other = idx(&[&[5, 6]]);
        assert!(c.get(&ka, &other).is_none(),
                "exact index compare must reject a colliding key");
        assert_eq!(c.get(&ka, &ia), Some(&10));
    }

    #[test]
    fn eviction_is_lru() {
        let mut c: GatherCache<u32> = GatherCache::new(2);
        let ia = idx(&[&[1]]);
        let ib = idx(&[&[2]]);
        let ic = idx(&[&[3]]);
        let (ka, kb, kc) =
            (GatherKey::new(&ia), GatherKey::new(&ib), GatherKey::new(&ic));
        c.insert(ka, ia.clone(), 1);
        c.insert(kb, ib.clone(), 2);
        c.get(&ka, &ia); // ka is now most recent
        c.insert(kc, ic.clone(), 3); // evicts kb
        assert_eq!(c.get(&ka, &ia), Some(&1));
        assert!(c.get(&kb, &ib).is_none(), "LRU entry should be evicted");
        assert_eq!(c.get(&kc, &ic), Some(&3));
    }

    #[test]
    fn reinsert_same_key_replaces_value() {
        let mut c: GatherCache<u32> = GatherCache::new(2);
        let ia = idx(&[&[7, 8]]);
        let ka = GatherKey::new(&ia);
        c.insert(ka, ia.clone(), 1);
        c.insert(ka, ia.clone(), 2);
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(&ka, &ia), Some(&2));
    }
}
