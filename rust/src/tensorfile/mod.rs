//! GWT1 tensor container reader/writer — rust side of the weights
//! interchange format (python/compile/tensorfile.py documents the layout).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

pub const MAGIC: &[u8; 4] = b"GWT1";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn code(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I32 => 1,
        }
    }
    fn from_code(c: u8) -> Result<Self> {
        match c {
            0 => Ok(DType::F32),
            1 => Ok(DType::I32),
            _ => bail!("unknown dtype code {c}"),
        }
    }
}

/// A host tensor: raw little-endian data + shape + dtype.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn from_f32(shape: Vec<usize>, values: &[f32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { dtype: DType::F32, shape, data }
    }

    pub fn from_i32(shape: Vec<usize>, values: &[i32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { dtype: DType::I32, shape, data }
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn to_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("tensor is not f32");
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn to_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("tensor is not i32");
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

pub type TensorMap = BTreeMap<String, Tensor>;

pub fn read<P: AsRef<Path>>(path: P) -> Result<TensorMap> {
    let mut file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    let mut buf = Vec::new();
    file.read_to_end(&mut buf)?;
    parse(&buf)
}

pub fn parse(buf: &[u8]) -> Result<TensorMap> {
    let mut r = Cursor { b: buf, pos: 0 };
    if r.take(4)? != MAGIC {
        bail!("bad magic");
    }
    let n = r.u32()? as usize;
    let mut metas = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = r.u16()? as usize;
        let name = String::from_utf8(r.take(name_len)?.to_vec())
            .context("tensor name not utf-8")?;
        let dtype = DType::from_code(r.u8()?)?;
        let ndim = r.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(r.u32()? as usize);
        }
        let offset = r.u64()? as usize;
        let nbytes = r.u64()? as usize;
        metas.push((name, dtype, shape, offset, nbytes));
    }
    let total = r.u64()? as usize;
    let data_start = r.pos;
    if data_start + total > buf.len() {
        bail!(
            "data section truncated: need {} bytes, have {}",
            total,
            buf.len() - data_start
        );
    }
    let mut out = TensorMap::new();
    for (name, dtype, shape, offset, nbytes) in metas {
        let want = shape.iter().product::<usize>() * 4;
        if want != nbytes {
            bail!("{name}: shape {shape:?} implies {want} bytes, \
                   header says {nbytes}");
        }
        let start = data_start + offset;
        if start + nbytes > buf.len() {
            bail!("{name}: data out of range");
        }
        out.insert(
            name,
            Tensor { dtype, shape, data: buf[start..start + nbytes].to_vec() },
        );
    }
    Ok(out)
}

pub fn write<P: AsRef<Path>>(path: P, tensors: &TensorMap) -> Result<()> {
    let bytes = serialize(tensors);
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(&bytes)?;
    Ok(())
}

pub fn serialize(tensors: &TensorMap) -> Vec<u8> {
    let mut header = Vec::new();
    header.extend_from_slice(MAGIC);
    header.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    let mut offset = 0u64;
    for (name, t) in tensors {
        let raw = name.as_bytes();
        header.extend_from_slice(&(raw.len() as u16).to_le_bytes());
        header.extend_from_slice(raw);
        header.push(t.dtype.code());
        header.push(t.shape.len() as u8);
        for d in &t.shape {
            header.extend_from_slice(&(*d as u32).to_le_bytes());
        }
        header.extend_from_slice(&offset.to_le_bytes());
        header.extend_from_slice(&(t.data.len() as u64).to_le_bytes());
        offset += t.data.len() as u64;
    }
    header.extend_from_slice(&offset.to_le_bytes());
    for t in tensors.values() {
        header.extend_from_slice(&t.data);
    }
    header
}

struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("unexpected eof at byte {}", self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }
    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }
    fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes(s.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::rng::XorShift64Star;

    fn sample() -> TensorMap {
        let mut m = TensorMap::new();
        m.insert("a".into(), Tensor::from_f32(vec![2, 3],
                                              &[1., 2., 3., 4., 5., 6.]));
        m.insert("b.idx".into(), Tensor::from_i32(vec![4], &[-1, 0, 7, 42]));
        m.insert("empty".into(), Tensor::from_f32(vec![0], &[]));
        m
    }

    #[test]
    fn roundtrip_memory() {
        let m = sample();
        let bytes = serialize(&m);
        let got = parse(&bytes).unwrap();
        assert_eq!(got, m);
    }

    #[test]
    fn roundtrip_file() {
        let dir = std::env::temp_dir().join("griffin_tf_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let m = sample();
        write(&path, &m).unwrap();
        assert_eq!(read(&path).unwrap(), m);
    }

    #[test]
    fn rejects_corruption() {
        let m = sample();
        let mut bytes = serialize(&m);
        bytes[0] = b'X'; // magic
        assert!(parse(&bytes).is_err());

        let bytes = serialize(&m);
        assert!(parse(&bytes[..bytes.len() - 2]).is_err(), "truncated");
    }

    #[test]
    fn f32_i32_accessors() {
        let t = Tensor::from_f32(vec![3], &[1.5, -2.0, 0.0]);
        assert_eq!(t.to_f32().unwrap(), vec![1.5, -2.0, 0.0]);
        assert!(t.to_i32().is_err());
    }

    /// Property: random tensor maps survive serialize→parse.
    #[test]
    fn prop_roundtrip_generated() {
        let mut rng = XorShift64Star::new(42);
        for _ in 0..50 {
            let mut m = TensorMap::new();
            let n = rng.below(5) + 1;
            for i in 0..n {
                let ndim = rng.below(4);
                let shape: Vec<usize> =
                    (0..ndim).map(|_| rng.below(5) + 1).collect();
                let count: usize = shape.iter().product();
                if rng.below(2) == 0 {
                    let vals: Vec<f32> = (0..count)
                        .map(|_| rng.unit_f64() as f32 - 0.5)
                        .collect();
                    m.insert(format!("t{i}"),
                             Tensor::from_f32(shape, &vals));
                } else {
                    let vals: Vec<i32> = (0..count)
                        .map(|_| rng.below(100) as i32 - 50)
                        .collect();
                    m.insert(format!("t{i}"),
                             Tensor::from_i32(shape, &vals));
                }
            }
            let bytes = serialize(&m);
            assert_eq!(parse(&bytes).unwrap(), m);
        }
    }

    /// Cross-language: read a file written by python (if artifacts exist).
    #[test]
    fn reads_python_weights_if_present() {
        let path = crate::test_support::artifact_path(
            "tiny-swiglu/weights.bin");
        if !path.exists() {
            crate::skip!("tensorfile: {path:?} missing (run make \
                          artifacts)");
        }
        let m = read(&path).unwrap();
        assert!(m.contains_key("tok_emb"));
        assert!(m.contains_key("w1"));
        let w1 = &m["w1"];
        assert_eq!(w1.shape.len(), 3); // [L, F, D]
        assert_eq!(w1.dtype, DType::F32);
        let vals = w1.to_f32().unwrap();
        assert!(vals.iter().all(|v| v.is_finite()));
    }
}
