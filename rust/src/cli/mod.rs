//! Tiny CLI argument parser (substrate: clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments
//! and subcommands. Unknown flags are errors; every command declares its
//! accepted options so `--help` output is generated consistently.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got {v:?}")),
        }
    }
}

pub fn parse(argv: &[String], specs: &[OptSpec]) -> Result<Args> {
    let mut args = Args::default();
    for spec in specs {
        if let Some(d) = spec.default {
            args.values.insert(spec.name.to_string(), d.to_string());
        }
    }
    let find = |name: &str| specs.iter().find(|s| s.name == name);

    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(rest) = a.strip_prefix("--") {
            let (name, inline) = match rest.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (rest, None),
            };
            let spec = match find(name) {
                Some(s) => s,
                None => bail!("unknown option --{name}"),
            };
            if spec.takes_value {
                let v = match inline {
                    Some(v) => v,
                    None => {
                        i += 1;
                        if i >= argv.len() {
                            bail!("--{name} expects a value");
                        }
                        argv[i].clone()
                    }
                };
                args.values.insert(name.to_string(), v);
            } else {
                if inline.is_some() {
                    bail!("--{name} does not take a value");
                }
                args.flags.insert(name.to_string(), true);
            }
        } else {
            args.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(args)
}

pub fn usage(cmd: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut s = format!("{cmd} — {about}\n\noptions:\n");
    for o in specs {
        let val = if o.takes_value { " <value>" } else { "" };
        let def = o
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("  --{}{val}\n      {}{def}\n", o.name, o.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "model", takes_value: true,
                      default: Some("tiny-swiglu"), help: "model config" },
            OptSpec { name: "steps", takes_value: true, default: None,
                      help: "step count" },
            OptSpec { name: "verbose", takes_value: false, default: None,
                      help: "chatty" },
        ]
    }

    fn argv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&argv(&[]), &specs()).unwrap();
        assert_eq!(a.get("model"), Some("tiny-swiglu"));
        assert_eq!(a.get("steps"), None);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn values_and_flags() {
        let a = parse(
            &argv(&["--model", "small-swiglu", "--verbose", "pos1",
                    "--steps=10", "pos2"]),
            &specs(),
        )
        .unwrap();
        assert_eq!(a.get("model"), Some("small-swiglu"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 10);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn errors() {
        assert!(parse(&argv(&["--nope"]), &specs()).is_err());
        assert!(parse(&argv(&["--steps"]), &specs()).is_err());
        assert!(parse(&argv(&["--verbose=1"]), &specs()).is_err());
        let a = parse(&argv(&["--steps", "abc"]), &specs()).unwrap();
        assert!(a.usize_or("steps", 0).is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = usage("serve", "run the server", &specs());
        assert!(u.contains("--model"));
        assert!(u.contains("default: tiny-swiglu"));
    }
}
