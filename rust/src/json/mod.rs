//! Minimal JSON codec (substrate: serde is unavailable in the offline
//! build environment — see DESIGN.md §Substitutions).
//!
//! Supports the full JSON grammar minus some escape exotica (\u surrogate
//! pairs are handled). Numbers parse as f64; integer accessors check
//! round-tripping. Object key order is preserved (Vec of pairs) so
//! serialized manifests diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => {
                Some(*n as i64)
            }
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup (linear; manifests are small).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(o) => o.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    pub fn obj_to_map(&self) -> Option<BTreeMap<String, &Value>> {
        self.as_obj().map(|o| {
            o.iter().map(|(k, v)| (k.clone(), v)).collect()
        })
    }
}

// --------------------------------------------------------------------------
// parsing
// --------------------------------------------------------------------------

pub fn parse(text: &str) -> Result<Value, JsonError> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\')
                                || self.bump() != Some(b'u')
                            {
                                return Err(
                                    self.err("missing low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        out.push(
                            char::from_u32(cp)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    if start + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = start + len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// --------------------------------------------------------------------------
// serialization
// --------------------------------------------------------------------------

pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => write_string(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(v, out);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, v)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(v, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// convenience builders --------------------------------------------------

pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

pub fn n(v: f64) -> Value {
    Value::Num(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Value::Null);
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\nb\t\"c\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"c\" é 😀");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = parse("\"héllo 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo 世界");
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1 2", "\"\\x\"",
                    "{\"a\" 1}"] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"a":[1,2,3],"b":{"c":"d"},"e":null,"f":true,"g":1.5}"#,
            r#"[[],{},"",0]"#,
            r#""tab\tnewline\n""#,
        ];
        for c in cases {
            let v = parse(c).unwrap();
            let s = to_string(&v);
            assert_eq!(parse(&s).unwrap(), v, "roundtrip {c}");
        }
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> =
            v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn integer_accessors() {
        assert_eq!(parse("7").unwrap().as_i64(), Some(7));
        assert_eq!(parse("7.5").unwrap().as_i64(), None);
        assert_eq!(parse("-3").unwrap().as_usize(), None);
    }

    /// mini property test: random values survive serialize→parse.
    #[test]
    fn prop_roundtrip_generated() {
        let mut rng = crate::workload::rng::XorShift64Star::new(99);
        for _ in 0..200 {
            let v = gen_value(&mut rng, 0);
            let s = to_string(&v);
            assert_eq!(parse(&s).unwrap(), v, "failed on {s}");
        }
    }

    fn gen_value(
        rng: &mut crate::workload::rng::XorShift64Star,
        depth: usize,
    ) -> Value {
        let choice = if depth > 3 { rng.below(4) } else { rng.below(6) };
        match choice {
            0 => Value::Null,
            1 => Value::Bool(rng.below(2) == 0),
            2 => Value::Num((rng.below(2000) as f64 - 1000.0) / 8.0),
            3 => {
                let len = rng.below(8);
                let s: String = (0..len)
                    .map(|_| {
                        let c = rng.below(96) as u8 + 32;
                        c as char
                    })
                    .collect();
                Value::Str(s)
            }
            4 => {
                let len = rng.below(4);
                Value::Arr((0..len).map(|_| gen_value(rng, depth + 1))
                    .collect())
            }
            _ => {
                let len = rng.below(4);
                Value::Obj(
                    (0..len)
                        .map(|i| {
                            (format!("k{i}"), gen_value(rng, depth + 1))
                        })
                        .collect(),
                )
            }
        }
    }
}
