//! Small shared helpers.

use sha2::{Digest, Sha256};

/// Hex-encoded SHA-256 of a byte slice (cross-language corpus pinning).
pub fn sha256_hex(data: &[u8]) -> String {
    let mut h = Sha256::new();
    h.update(data);
    let out = h.finalize();
    out.iter().map(|b| format!("{b:02x}")).collect()
}

/// Mean of an f64 slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Percentile via linear interpolation on a sorted copy (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Indices of the top-k values (descending), ties broken by lower index.
///
/// Perf (EXPERIMENTS.md §Perf): O(n + k log k) partition on
/// order-preserving integer keys instead of a full float sort — selection
/// over Llama-7B-scale statistics (32 x 11008) dropped 75.7 ms → 5.9 ms
/// (12.8x), keeping the paper's "negligible selection overhead" claim true
/// in the coordinator (vs seconds of prefill at that scale).
pub fn top_k_indices(xs: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(xs.len());
    if k == 0 {
        return Vec::new();
    }
    // Branchless integer keys: map f32 bits to an order-preserving u32
    // (sign-flip trick; NaN treated as -inf), pack value-desc/index-asc
    // into one u64 so partition + sort run on plain integer compares.
    let order_bits = |v: f32| -> u32 {
        let v = if v.is_nan() { f32::NEG_INFINITY } else { v };
        let b = v.to_bits();
        if b & 0x8000_0000 != 0 { !b } else { b | 0x8000_0000 }
    };
    let mut keys: Vec<u64> = xs
        .iter()
        .enumerate()
        .map(|(i, &v)| {
            ((!order_bits(v) as u64) << 32) | (i as u32 as u64)
        })
        .collect();
    if k < keys.len() {
        keys.select_nth_unstable(k - 1);
        keys.truncate(k);
    }
    keys.sort_unstable();
    keys.into_iter().map(|key| (key & 0xFFFF_FFFF) as usize).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sha256_known_vector() {
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
    }

    #[test]
    fn stats() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!(stddev(&[1.0, 1.0, 1.0]) < 1e-12);
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 50.0), 2.5);
        assert_eq!(percentile(&[5.0], 99.0), 5.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn top_k() {
        let xs = [0.1f32, 0.9, 0.5, 0.9, 0.2];
        assert_eq!(top_k_indices(&xs, 2), vec![1, 3]); // tie -> lower index
        assert_eq!(top_k_indices(&xs, 0), Vec::<usize>::new());
        assert_eq!(top_k_indices(&xs, 10).len(), 5);
    }
}
