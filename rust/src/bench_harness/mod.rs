//! Timing/statistics harness (substrate: criterion is unavailable in the
//! offline environment). cargo-bench targets use `harness = false` and
//! call into this module.
//!
//! Methodology: warmup runs (excluded), then timed iterations with
//! mean/stddev/p50/p90; results are printed as a table and appended to
//! results/bench_*.csv so EXPERIMENTS.md can reference them.

use std::time::Instant;

use crate::util::{mean, percentile, stddev};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub stddev_ms: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>6} it  {:>10.3} ±{:>8.3} ms  p50 {:>9.3}  p90 {:>9.3}",
            self.name, self.iters, self.mean_ms, self.stddev_ms,
            self.p50_ms, self.p90_ms
        )
    }

    pub fn csv_row(&self) -> String {
        format!(
            "{},{},{:.4},{:.4},{:.4},{:.4},{:.4},{:.4}",
            self.name, self.iters, self.mean_ms, self.stddev_ms,
            self.p50_ms, self.p90_ms, self.min_ms, self.max_ms
        )
    }
}

pub const CSV_HEADER: &str =
    "name,iters,mean_ms,stddev_ms,p50_ms,p90_ms,min_ms,max_ms";

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                         mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    summarize(name, &samples)
}

/// Adaptive: run until `budget_ms` of measurement time or `max_iters`.
pub fn bench_for<F: FnMut()>(name: &str, warmup: usize, budget_ms: f64,
                             max_iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < max_iters
        && (samples.len() < 3
            || start.elapsed().as_secs_f64() * 1e3 < budget_ms)
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    summarize(name, &samples)
}

pub fn summarize(name: &str, samples_ms: &[f64]) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        iters: samples_ms.len(),
        mean_ms: mean(samples_ms),
        stddev_ms: stddev(samples_ms),
        p50_ms: percentile(samples_ms, 50.0),
        p90_ms: percentile(samples_ms, 90.0),
        min_ms: samples_ms.iter().cloned().fold(f64::INFINITY, f64::min),
        max_ms: samples_ms.iter().cloned().fold(0.0, f64::max),
    }
}

/// Collects results, prints rows as they come, saves CSV at the end.
pub struct Reporter {
    pub results: Vec<BenchResult>,
    csv_name: String,
}

impl Reporter {
    pub fn new(csv_name: &str) -> Self {
        println!("{:-<100}", "");
        Reporter { results: Vec::new(), csv_name: csv_name.to_string() }
    }

    pub fn add(&mut self, r: BenchResult) {
        println!("{}", r.row());
        self.results.push(r);
    }

    pub fn finish(self) {
        let path = crate::test_support::results_path(&self.csv_name);
        let mut out = String::from(CSV_HEADER);
        out.push('\n');
        for r in &self.results {
            out.push_str(&r.csv_row());
            out.push('\n');
        }
        if let Err(e) = std::fs::write(&path, out) {
            eprintln!("warning: could not write {path:?}: {e}");
        } else {
            println!("-> {}", path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let r = bench("noop", 2, 10, || n += 1);
        assert_eq!(r.iters, 10);
        assert_eq!(n, 12); // warmup + iters
        assert!(r.mean_ms >= 0.0);
        assert!(r.min_ms <= r.p50_ms && r.p50_ms <= r.max_ms);
    }

    #[test]
    fn bench_for_respects_budget() {
        let r = bench_for("sleepy", 0, 30.0, 1000, || {
            std::thread::sleep(std::time::Duration::from_millis(5))
        });
        assert!(r.iters >= 3 && r.iters < 20, "iters = {}", r.iters);
    }

    #[test]
    fn summarize_known_values() {
        let r = summarize("x", &[1.0, 2.0, 3.0]);
        assert!((r.mean_ms - 2.0).abs() < 1e-12);
        assert_eq!(r.min_ms, 1.0);
        assert_eq!(r.max_ms, 3.0);
    }

    #[test]
    fn csv_roundtrip_fields() {
        let r = summarize("a,b", &[1.0]); // comma in name is naughty but
        let row = r.csv_row();            // must not panic
        assert!(row.contains("a,b"));
    }
}
