//! Token sampling over logits (Layer-3 hot path — the decode loop calls
//! this once per step per sequence).
//!
//! Greedy / temperature / top-k / top-p, plus a composable `SamplerSpec`.
//! The PRNG is the same xorshift64* used everywhere else, so sampled
//! generations are reproducible given a request seed. Fused-eligible
//! specs (greedy / top-k) served by the continuous scheduler instead
//! draw from the on-device xorshift32 stream, mirrored host-side by
//! [`DeviceSampler`] — also seed-reproducible, and independent of
//! whether individual ticks ran on the fused or host path.

use crate::workload::rng::XorShift64Star;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SamplerSpec {
    Greedy,
    /// temperature > 0; 1.0 = raw distribution
    Temperature(f32),
    /// top-k truncation then temperature
    TopK { k: usize, temperature: f32 },
    /// nucleus sampling then temperature
    TopP { p: f32, temperature: f32 },
}

impl Default for SamplerSpec {
    fn default() -> Self {
        SamplerSpec::Greedy
    }
}

pub struct Sampler {
    pub spec: SamplerSpec,
    rng: XorShift64Star,
    /// scratch buffer reused across steps (no allocation in the hot loop)
    scratch: Vec<(usize, f32)>,
}

impl Sampler {
    pub fn new(spec: SamplerSpec, seed: u64) -> Self {
        Sampler { spec, rng: XorShift64Star::new(seed), scratch: Vec::new() }
    }

    /// Pick the next token id from a logits slice.
    pub fn sample(&mut self, logits: &[f32]) -> usize {
        match self.spec {
            SamplerSpec::Greedy => argmax(logits),
            SamplerSpec::Temperature(t) => {
                self.sample_truncated(logits, logits.len(), 1.0, t)
            }
            SamplerSpec::TopK { k, temperature } => {
                self.sample_truncated(logits, k.max(1), 1.0, temperature)
            }
            SamplerSpec::TopP { p, temperature } => {
                self.sample_truncated(logits, logits.len(), p, temperature)
            }
        }
    }

    fn sample_truncated(
        &mut self,
        logits: &[f32],
        k: usize,
        p: f32,
        temperature: f32,
    ) -> usize {
        if temperature <= 1e-6 {
            return argmax(logits);
        }
        let inv_t = 1.0 / temperature;
        self.scratch.clear();
        self.scratch
            .extend(logits.iter().enumerate().map(|(i, &l)| (i, l)));
        self.scratch.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal)
        });
        let k = k.min(self.scratch.len());

        // softmax over the temperature-scaled top-k, accumulating until
        // the nucleus mass p is covered
        let max_l = self.scratch[0].1;
        let mut cum = 0.0f64;
        let mut cut = k;
        let mut weights = Vec::with_capacity(k);
        let denom: f64 = self.scratch[..k]
            .iter()
            .map(|(_, l)| (((l - max_l) * inv_t) as f64).exp())
            .sum();
        for (j, (_, l)) in self.scratch[..k].iter().enumerate() {
            let w = (((l - max_l) * inv_t) as f64).exp() / denom;
            weights.push(w);
            cum += w;
            if cum >= p as f64 {
                cut = j + 1;
                break;
            }
        }
        let total: f64 = weights[..cut].iter().sum();
        let mut r = self.rng.unit_f64() * total;
        for (j, w) in weights[..cut].iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return self.scratch[j].0;
            }
        }
        self.scratch[cut - 1].0
    }
}

// ---------------------------------------------------------------------------
// fused-sampling ABI mirror (python/compile/model.py sample_tokens)
// ---------------------------------------------------------------------------

/// Static top-k truncation bucket compiled into every `decode_sample_*`
/// executable — must equal `model.SAMPLE_TOPK` on the python side (the
/// manifest also records it per executable as `sample_topk`).
pub const SAMPLE_TOPK: usize = 32;

/// Can this sampler spec run on the fused on-device path? The compiled
/// sampler supports greedy and top-k-with-temperature up to the static
/// truncation bucket; temperature-over-full-vocab and nucleus sampling
/// keep the host-logits path.
pub fn fused_eligible(spec: SamplerSpec, sample_topk: usize) -> bool {
    match spec {
        SamplerSpec::Greedy => true,
        SamplerSpec::TopK { k, .. } => k >= 1 && k <= sample_topk,
        SamplerSpec::Temperature(_) | SamplerSpec::TopP { .. } => false,
    }
}

/// Per-slot device sampling parameters (temp, topk) for a fused-eligible
/// spec. Greedy is encoded as temp = 0 (the device treats temp <= 1e-6
/// as argmax).
pub fn device_params(spec: SamplerSpec) -> (f32, i32) {
    match spec {
        SamplerSpec::Greedy => (0.0, 1),
        SamplerSpec::TopK { k, temperature } => {
            (temperature, k.max(1) as i32)
        }
        // not fused-eligible; greedy placeholders (never uploaded —
        // the scheduler routes these specs to the host-logits path)
        SamplerSpec::Temperature(_) | SamplerSpec::TopP { .. } => (0.0, 1),
    }
}

/// Derive the initial xorshift32 state from a request seed (both sides
/// of the ABI use this fold; the state must never be zero).
pub fn seed_state(seed: u64) -> u32 {
    let s = (seed as u32) ^ ((seed >> 32) as u32);
    if s == 0 {
        0x9E37_79B9
    } else {
        s
    }
}

/// One step of the xorshift32 recurrence — the device RNG of the fused
/// sampling ABI (model.py `_xorshift32`). Public because the host mirror
/// ([`DeviceSampler`]) and the CPU reference substrate
/// (`runtime::cpu`) must advance the identical stream.
pub fn xorshift32(mut s: u32) -> u32 {
    s ^= s << 13;
    s ^= s >> 17;
    s ^= s << 5;
    s
}

/// One fused-sampler lane step over decode logits, shared by
/// [`DeviceSampler::sample`] (the host mirror) and the CPU reference
/// substrate's executable interpreter — a single implementation, so the
/// two sides of the ABI cannot drift and fused-vs-host parity holds
/// bit-for-bit by construction.
///
/// `temp`/`topk` are the raw per-slot device parameters (see
/// model.sample_tokens): `temp <= 1e-6` selects greedy argmax, otherwise
/// top-min(`topk`, `cap`) temperature sampling, where `cap` is the
/// executable's compiled truncation bucket (`sample_topk` in its
/// manifest entry). The RNG advances exactly once per call regardless of
/// the path taken (data-independent, like the device stream). Returns
/// (token, advanced state).
pub fn sample_lane(logits: &[f32], temp: f32, topk: i32, state: u32,
                   cap: usize) -> (usize, u32) {
    let mut scratch = Vec::new();
    let mut cum = Vec::new();
    sample_lane_with_scratch(logits, temp, topk, state, cap,
                             &mut scratch, &mut cum)
}

/// [`sample_lane`] with caller-owned scratch buffers, for callers that
/// run many lanes per step (the CPU substrate's per-slot sampler loop)
/// and want zero allocation after warm-up — the same reuse discipline
/// [`DeviceSampler`] applies to its own scratch.
pub fn sample_lane_with_scratch(
    logits: &[f32], temp: f32, topk: i32, state: u32, cap: usize,
    scratch: &mut Vec<usize>, cum: &mut Vec<f32>,
) -> (usize, u32) {
    let state = xorshift32(state);
    let u = (state >> 8) as f32 * (1.0 / 16_777_216.0);
    let tok = sample_lane_core(logits, temp, topk.max(1) as usize, u, cap,
                               scratch, cum);
    (tok, state)
}

/// The arithmetic core of one sampler lane: uniform draw `u` already
/// taken from the stream. Scratch buffers are caller-owned so the
/// per-slot host mirror can reuse them across steps (no allocation in
/// the hot loop); they are cleared here before use.
fn sample_lane_core(logits: &[f32], temp: f32, topk: usize, u: f32,
                    cap: usize, scratch: &mut Vec<usize>,
                    cum: &mut Vec<f32>) -> usize {
    if temp <= 1e-6 {
        return argmax(logits);
    }
    let kk = cap.max(1).min(logits.len());
    // top-kk by (logit desc, index asc) — the composite key gives a
    // total order reproducing lax.top_k's lower-index-first ties,
    // so an O(V) partial selection replaces a full O(V log V) sort
    let desc = |a: &usize, b: &usize| {
        logits[*b]
            .partial_cmp(&logits[*a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(b))
    };
    scratch.clear();
    scratch.extend(0..logits.len());
    if kk < scratch.len() {
        scratch.select_nth_unstable_by(kk - 1, desc);
        scratch.truncate(kk);
    }
    scratch.sort_by(desc);
    let top = &scratch[..kk];
    let v0 = logits[top[0]];
    let safe_t = temp.max(1e-6);
    cum.clear();
    let mut total = 0f32;
    for (j, &i) in top.iter().enumerate() {
        let w = if j < topk {
            ((logits[i] - v0) / safe_t).exp()
        } else {
            0.0
        };
        total += w;
        cum.push(total);
    }
    let r = u * total;
    for (j, &c) in cum.iter().enumerate() {
        if c >= r {
            return top[j];
        }
    }
    top[kk - 1]
}

/// Host mirror of the on-device sampler (`model.sample_tokens`): same
/// RNG recurrence, same top-k/temperature arithmetic in f32, same
/// tie-breaking (stable order). Used by the artifact-gated parity tests
/// to predict fused `decode_sample_*` tokens from host-side logits.
///
/// Parity caveat: the integer RNG stream is bit-exact by construction;
/// the f32 exp/cumsum can differ from XLA's in the last ulp, so a token
/// mismatch is possible iff the uniform draw lands exactly on a
/// boundary — vanishingly unlikely and deterministic for a fixed seed.
pub struct DeviceSampler {
    pub spec: SamplerSpec,
    state: u32,
    /// compiled truncation bucket of the executable being mirrored
    /// (`sample_topk` from its manifest entry)
    cap: usize,
    /// scratch reused across steps (no allocation in the hot loop —
    /// host-fallback ticks sample through this mirror per slot)
    scratch: Vec<usize>,
    cum: Vec<f32>,
}

impl DeviceSampler {
    pub fn new(spec: SamplerSpec, seed: u64) -> Self {
        Self::with_cap(spec, seed, SAMPLE_TOPK)
    }

    /// Mirror an executable compiled with a different truncation bucket
    /// (read `sample_topk` from its manifest entry rather than assuming
    /// the current SAMPLE_TOPK constant).
    pub fn with_cap(spec: SamplerSpec, seed: u64, cap: usize) -> Self {
        DeviceSampler {
            spec,
            state: seed_state(seed),
            cap: cap.max(1),
            scratch: Vec::new(),
            cum: Vec::new(),
        }
    }

    /// Current xorshift32 state (upload this to resume the device
    /// stream exactly where the mirror stands).
    pub fn state(&self) -> u32 {
        self.state
    }

    /// Advance the stream one step without sampling — call once per
    /// fused tick to keep the mirror in lockstep with the device, whose
    /// RNG advances exactly once per executable call regardless of the
    /// sampling path taken.
    pub fn skip(&mut self) {
        self.state = xorshift32(self.state);
    }

    /// One sampling step. The RNG advances on every call regardless of
    /// the path taken (matching the device's data-independent stream).
    /// Delegates to `sample_lane_core` — the same arithmetic the CPU
    /// reference substrate executes — with scratch buffers reused across
    /// steps (no allocation on host-fallback ticks).
    pub fn sample(&mut self, logits: &[f32]) -> usize {
        self.state = xorshift32(self.state);
        let u = (self.state >> 8) as f32 * (1.0 / 16_777_216.0);
        let (temp, topk) = match self.spec {
            SamplerSpec::Greedy => (0.0, 1usize),
            SamplerSpec::TopK { k, temperature } => {
                (temperature, k.max(1))
            }
            // ineligible specs never reach the fused path; mirror the
            // device's greedy fallback for robustness
            _ => (0.0, 1usize),
        };
        sample_lane_core(logits, temp, topk, u, self.cap,
                         &mut self.scratch, &mut self.cum)
    }
}

pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

/// log-softmax value of one index (perplexity scoring).
pub fn log_softmax_at(logits: &[f32], index: usize) -> f32 {
    let max_l = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse: f64 = logits
        .iter()
        .map(|&l| ((l - max_l) as f64).exp())
        .sum::<f64>()
        .ln()
        + max_l as f64;
    logits[index] as f64 as f32 - lse as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[-1.0, -2.0]), 0);
    }

    #[test]
    fn greedy_deterministic() {
        let mut s = Sampler::new(SamplerSpec::Greedy, 1);
        let logits = vec![0.0, 3.0, 1.0];
        for _ in 0..10 {
            assert_eq!(s.sample(&logits), 1);
        }
    }

    #[test]
    fn zero_temperature_degenerates_to_greedy() {
        let mut s = Sampler::new(SamplerSpec::Temperature(0.0), 1);
        assert_eq!(s.sample(&[0.0, 5.0, 1.0]), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let mut s =
            Sampler::new(SamplerSpec::TopK { k: 2, temperature: 1.0 }, 7);
        let logits = vec![10.0, 9.5, -50.0, -60.0];
        for _ in 0..200 {
            let t = s.sample(&logits);
            assert!(t == 0 || t == 1, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn top_p_restricts_support() {
        // token 0 has ~all the mass; p=0.5 keeps only it
        let mut s =
            Sampler::new(SamplerSpec::TopP { p: 0.5, temperature: 1.0 }, 7);
        let logits = vec![10.0, 0.0, 0.0, 0.0];
        for _ in 0..100 {
            assert_eq!(s.sample(&logits), 0);
        }
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let mut s = Sampler::new(SamplerSpec::Temperature(1.0), 3);
        let logits = vec![1.0, 1.0, 1.0];
        let mut seen = [false; 3];
        for _ in 0..300 {
            seen[s.sample(&logits)] = true;
        }
        assert!(seen.iter().all(|&b| b), "uniform logits reach all tokens");
    }

    #[test]
    fn sampling_reproducible_by_seed() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32).sin()).collect();
        let run = |seed| {
            let mut s =
                Sampler::new(SamplerSpec::Temperature(0.8), seed);
            (0..32).map(|_| s.sample(&logits)).collect::<Vec<_>>()
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }

    #[test]
    fn seed_state_never_zero() {
        assert_ne!(seed_state(0), 0, "xorshift32 must not start at 0");
        assert_ne!(seed_state(u64::MAX), 0);
        // the fold mixes both halves
        assert_ne!(seed_state(1), seed_state(1 << 32 | 1));
        assert_ne!(seed_state(7), seed_state(8));
    }

    #[test]
    fn fused_eligibility_matches_compiled_sampler() {
        assert!(fused_eligible(SamplerSpec::Greedy, SAMPLE_TOPK));
        assert!(fused_eligible(
            SamplerSpec::TopK { k: SAMPLE_TOPK, temperature: 0.7 },
            SAMPLE_TOPK
        ));
        assert!(!fused_eligible(
            SamplerSpec::TopK { k: SAMPLE_TOPK + 1, temperature: 0.7 },
            SAMPLE_TOPK
        ));
        assert!(!fused_eligible(SamplerSpec::Temperature(1.0),
                                SAMPLE_TOPK));
        assert!(!fused_eligible(
            SamplerSpec::TopP { p: 0.9, temperature: 1.0 },
            SAMPLE_TOPK
        ));
    }

    #[test]
    fn device_sampler_greedy_matches_argmax_and_advances_rng() {
        let logits = vec![0.1f32, 2.0, -1.0, 0.5];
        let mut s = DeviceSampler::new(SamplerSpec::Greedy, 42);
        let s0 = format!("{:?}", s.state);
        for _ in 0..5 {
            assert_eq!(s.sample(&logits), 1);
        }
        // the stream advanced even though greedy never consumed it
        assert_ne!(format!("{:?}", s.state), s0);
    }

    #[test]
    fn device_sampler_restricts_to_topk_and_is_seed_deterministic() {
        let logits: Vec<f32> =
            (0..64).map(|i| ((i * 37) % 64) as f32 * 0.1).collect();
        let top4: Vec<usize> = {
            let mut ix: Vec<usize> = (0..64).collect();
            ix.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
            ix[..4].to_vec()
        };
        let spec = SamplerSpec::TopK { k: 4, temperature: 1.0 };
        let run = |seed| {
            let mut s = DeviceSampler::new(spec, seed);
            (0..64).map(|_| s.sample(&logits)).collect::<Vec<_>>()
        };
        let a = run(9);
        assert_eq!(a, run(9), "same seed, same stream");
        assert_ne!(a, run(10));
        for t in &a {
            assert!(top4.contains(t), "sampled {t} outside top-4 {top4:?}");
        }
        let distinct: std::collections::HashSet<_> = a.iter().collect();
        assert!(distinct.len() > 1, "temperature should move around");
    }

    #[test]
    fn device_sampler_tiny_temperature_degenerates_to_greedy() {
        let logits = vec![0.0f32, 5.0, 1.0];
        let mut s = DeviceSampler::new(
            SamplerSpec::TopK { k: 3, temperature: 0.0 }, 3);
        assert_eq!(s.sample(&logits), 1);
    }

    #[test]
    fn device_sampler_equals_raw_lane_across_interleavings() {
        // Property: the host mirror and the raw lane function (the code
        // the CPU substrate executes per slot) produce identical token
        // streams and identical RNG states for random (temperature,
        // top_k <= cap, seed) triples, under random skip()/sample()
        // interleavings — including non-default caps (the with_cap
        // manifest path).
        use crate::workload::rng::XorShift64Star;
        let mut rng = XorShift64Star::new(2024);
        for case in 0..200 {
            let cap = [1usize, 4, 16, SAMPLE_TOPK][case % 4];
            let k = 1 + rng.below(cap);
            let temp = if case % 7 == 0 {
                0.0
            } else {
                0.05 + rng.unit_f64() as f32 * 1.8
            };
            let spec = if temp <= 1e-6 {
                SamplerSpec::Greedy
            } else {
                SamplerSpec::TopK { k, temperature: temp }
            };
            let seed = rng.next_u64();
            let mut mirror = DeviceSampler::with_cap(spec, seed, cap);
            let mut state = seed_state(seed);
            let (dev_temp, dev_topk) = device_params(spec);
            for _step in 0..24 {
                let v = 8 + rng.below(56);
                let logits: Vec<f32> = (0..v)
                    .map(|_| (rng.unit_f64() as f32 - 0.5) * 8.0)
                    .collect();
                if rng.below(3) == 0 {
                    mirror.skip();
                    state = xorshift32(state);
                } else {
                    let a = mirror.sample(&logits);
                    let (b, ns) =
                        sample_lane(&logits, dev_temp, dev_topk, state, cap);
                    state = ns;
                    assert_eq!(a, b,
                               "token drift: case {case} spec {spec:?}");
                    // identical tokens + the shared log_softmax_at imply
                    // identical logprob streams
                    let lp = log_softmax_at(&logits, a);
                    assert!(lp <= 0.0);
                }
                assert_eq!(mirror.state(), state,
                           "rng drift: case {case} spec {spec:?}");
            }
        }
    }

    #[test]
    fn device_params_encode_greedy_as_zero_temp() {
        assert_eq!(device_params(SamplerSpec::Greedy), (0.0, 1));
        assert_eq!(
            device_params(SamplerSpec::TopK { k: 8, temperature: 0.7 }),
            (0.7, 8)
        );
    }

    #[test]
    fn log_softmax_normalizes() {
        let logits = vec![1.0f32, 2.0, 3.0];
        let total: f64 = (0..3)
            .map(|i| (log_softmax_at(&logits, i) as f64).exp())
            .sum();
        assert!((total - 1.0).abs() < 1e-5);
    }
}
