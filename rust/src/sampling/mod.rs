//! Token sampling over logits (Layer-3 hot path — the decode loop calls
//! this once per step per sequence).
//!
//! Greedy / temperature / top-k / top-p, plus a composable `SamplerSpec`.
//! The PRNG is the same xorshift64* used everywhere else, so sampled
//! generations are reproducible given a request seed.

use crate::workload::rng::XorShift64Star;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SamplerSpec {
    Greedy,
    /// temperature > 0; 1.0 = raw distribution
    Temperature(f32),
    /// top-k truncation then temperature
    TopK { k: usize, temperature: f32 },
    /// nucleus sampling then temperature
    TopP { p: f32, temperature: f32 },
}

impl Default for SamplerSpec {
    fn default() -> Self {
        SamplerSpec::Greedy
    }
}

pub struct Sampler {
    pub spec: SamplerSpec,
    rng: XorShift64Star,
    /// scratch buffer reused across steps (no allocation in the hot loop)
    scratch: Vec<(usize, f32)>,
}

impl Sampler {
    pub fn new(spec: SamplerSpec, seed: u64) -> Self {
        Sampler { spec, rng: XorShift64Star::new(seed), scratch: Vec::new() }
    }

    /// Pick the next token id from a logits slice.
    pub fn sample(&mut self, logits: &[f32]) -> usize {
        match self.spec {
            SamplerSpec::Greedy => argmax(logits),
            SamplerSpec::Temperature(t) => {
                self.sample_truncated(logits, logits.len(), 1.0, t)
            }
            SamplerSpec::TopK { k, temperature } => {
                self.sample_truncated(logits, k.max(1), 1.0, temperature)
            }
            SamplerSpec::TopP { p, temperature } => {
                self.sample_truncated(logits, logits.len(), p, temperature)
            }
        }
    }

    fn sample_truncated(
        &mut self,
        logits: &[f32],
        k: usize,
        p: f32,
        temperature: f32,
    ) -> usize {
        if temperature <= 1e-6 {
            return argmax(logits);
        }
        let inv_t = 1.0 / temperature;
        self.scratch.clear();
        self.scratch
            .extend(logits.iter().enumerate().map(|(i, &l)| (i, l)));
        self.scratch.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal)
        });
        let k = k.min(self.scratch.len());

        // softmax over the temperature-scaled top-k, accumulating until
        // the nucleus mass p is covered
        let max_l = self.scratch[0].1;
        let mut cum = 0.0f64;
        let mut cut = k;
        let mut weights = Vec::with_capacity(k);
        let denom: f64 = self.scratch[..k]
            .iter()
            .map(|(_, l)| (((l - max_l) * inv_t) as f64).exp())
            .sum();
        for (j, (_, l)) in self.scratch[..k].iter().enumerate() {
            let w = (((l - max_l) * inv_t) as f64).exp() / denom;
            weights.push(w);
            cum += w;
            if cum >= p as f64 {
                cut = j + 1;
                break;
            }
        }
        let total: f64 = weights[..cut].iter().sum();
        let mut r = self.rng.unit_f64() * total;
        for (j, w) in weights[..cut].iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return self.scratch[j].0;
            }
        }
        self.scratch[cut - 1].0
    }
}

pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

/// log-softmax value of one index (perplexity scoring).
pub fn log_softmax_at(logits: &[f32], index: usize) -> f32 {
    let max_l = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse: f64 = logits
        .iter()
        .map(|&l| ((l - max_l) as f64).exp())
        .sum::<f64>()
        .ln()
        + max_l as f64;
    logits[index] as f64 as f32 - lse as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[-1.0, -2.0]), 0);
    }

    #[test]
    fn greedy_deterministic() {
        let mut s = Sampler::new(SamplerSpec::Greedy, 1);
        let logits = vec![0.0, 3.0, 1.0];
        for _ in 0..10 {
            assert_eq!(s.sample(&logits), 1);
        }
    }

    #[test]
    fn zero_temperature_degenerates_to_greedy() {
        let mut s = Sampler::new(SamplerSpec::Temperature(0.0), 1);
        assert_eq!(s.sample(&[0.0, 5.0, 1.0]), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let mut s =
            Sampler::new(SamplerSpec::TopK { k: 2, temperature: 1.0 }, 7);
        let logits = vec![10.0, 9.5, -50.0, -60.0];
        for _ in 0..200 {
            let t = s.sample(&logits);
            assert!(t == 0 || t == 1, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn top_p_restricts_support() {
        // token 0 has ~all the mass; p=0.5 keeps only it
        let mut s =
            Sampler::new(SamplerSpec::TopP { p: 0.5, temperature: 1.0 }, 7);
        let logits = vec![10.0, 0.0, 0.0, 0.0];
        for _ in 0..100 {
            assert_eq!(s.sample(&logits), 0);
        }
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let mut s = Sampler::new(SamplerSpec::Temperature(1.0), 3);
        let logits = vec![1.0, 1.0, 1.0];
        let mut seen = [false; 3];
        for _ in 0..300 {
            seen[s.sample(&logits)] = true;
        }
        assert!(seen.iter().all(|&b| b), "uniform logits reach all tokens");
    }

    #[test]
    fn sampling_reproducible_by_seed() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32).sin()).collect();
        let run = |seed| {
            let mut s =
                Sampler::new(SamplerSpec::Temperature(0.8), seed);
            (0..32).map(|_| s.sample(&logits)).collect::<Vec<_>>()
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }

    #[test]
    fn log_softmax_normalizes() {
        let logits = vec![1.0f32, 2.0, 3.0];
        let total: f64 = (0..3)
            .map(|i| (log_softmax_at(&logits, i) as f64).exp())
            .sum();
        assert!((total - 1.0).abs() < 1e-5);
    }
}
