//! Shared experiment machinery: engine loading, task evaluation loops,
//! CSV/markdown output.

use std::fmt::Write as _;

use anyhow::{Context, Result};

use crate::coordinator::engine::{Engine, Mode};
use crate::coordinator::sequence::GenRequest;
use crate::eval;
use crate::test_support::{artifact_path, results_path};
use crate::tokenizer::Tokenizer;
use crate::util::mean;
use crate::workload::tasks;

/// Load an engine for a config; prefers trained weights when available
/// unless `trained=false` is forced.
pub fn load_engine(config: &str, trained: bool) -> Result<Engine> {
    let dir = artifact_path(config);
    if !dir.join("manifest.json").exists() {
        anyhow::bail!(
            "artifacts for {config:?} missing — run `make artifacts`"
        );
    }
    Engine::load(&dir, trained)
}

pub fn engine_auto(config: &str) -> Result<Engine> {
    let dir = artifact_path(config);
    let manifest = crate::config::Manifest::load(&dir)?;
    load_engine(config, manifest.trained_weights_file.is_some())
}

/// Configs that have artifacts on disk, in a stable order.
pub fn available_configs() -> Vec<String> {
    let root = artifact_path("");
    let mut out = Vec::new();
    if let Ok(rd) = std::fs::read_dir(root) {
        for e in rd.flatten() {
            if e.path().join("manifest.json").exists() {
                out.push(e.file_name().to_string_lossy().into_owned());
            }
        }
    }
    out.sort();
    out
}

pub fn write_results(name: &str, content: &str) -> Result<()> {
    let path = results_path(name);
    std::fs::write(&path, content)
        .with_context(|| format!("writing {path:?}"))?;
    println!("-> {}", path.display());
    Ok(())
}

// ---------------------------------------------------------------------------
// task evaluation loops (shared by Figs 4-5 and Tables 1-5)
// ---------------------------------------------------------------------------

fn trim_generation(text: &str) -> &str {
    // generations continue past the target sentence; cut at the first
    // newline (document separator in tiny-lang)
    match text.find('\n') {
        Some(i) if i > 0 => &text[..i],
        _ => text,
    }
}

/// Summarization: greedy-generate the summary line, ROUGE vs reference.
pub fn eval_summarization(engine: &mut Engine, mode: Mode, n: usize,
                          max_new: usize) -> Result<eval::RougeScores> {
    let tok = Tokenizer::new();
    let samples = tasks::summarization(tasks::HELDOUT_SEED, n, 14);
    let (mut r1, mut r2, mut rl) = (0.0, 0.0, 0.0);
    for s in &samples {
        let req = GenRequest::greedy(
            0, tok.encode_with_bos(&s.prompt), max_new, mode);
        let resp = engine.generate(&req)?;
        let scores =
            eval::rouge_all(trim_generation(&resp.text), &s.reference);
        r1 += scores.rouge1;
        r2 += scores.rouge2;
        rl += scores.rougel;
    }
    let n = samples.len() as f64;
    Ok(eval::RougeScores {
        rouge1: 100.0 * r1 / n,
        rouge2: 100.0 * r2 / n,
        rougel: 100.0 * rl / n,
    })
}

/// QA: greedy-generate a short answer, token-F1/EM vs gold.
pub fn eval_qa(engine: &mut Engine, mode: Mode, n: usize)
               -> Result<(f64, f64)> {
    let tok = Tokenizer::new();
    let samples = tasks::qa(tasks::HELDOUT_SEED + 1, n, 10);
    let (mut f1, mut em) = (0.0, 0.0);
    for s in &samples {
        let req =
            GenRequest::greedy(0, tok.encode_with_bos(&s.prompt), 16, mode);
        let resp = engine.generate(&req)?;
        // answer continues "in short , the" -> prepend "the"
        let raw = trim_generation(&resp.text);
        let answer = format!("the{}", raw
            .split(" stands").next().unwrap_or(raw));
        f1 += eval::token_f1(&answer, &s.answer);
        em += eval::exact_match(&answer, &s.answer) as u8 as f64;
    }
    let n = samples.len() as f64;
    Ok((100.0 * f1 / n, 100.0 * em / n))
}

/// Multiple-choice accuracy: per choice, teacher-forced logprob under the
/// mode's generation-phase weights (the paper's "simulate generation for
/// one step" adaptation of classification, §5.1).
pub fn eval_classification(engine: &mut Engine, mode: Mode, n: usize,
                           n_choices: usize) -> Result<f64> {
    let tok = Tokenizer::new();
    let samples =
        tasks::classification(tasks::HELDOUT_SEED + 2, n, n_choices, 8);
    let mut correct = 0usize;
    for s in &samples {
        // continuations follow the in-training format: sentences are
        // space-separated within a document body
        let prompt = tok.encode_with_bos(&s.context);
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (ci, choice) in s.choices.iter().enumerate() {
            let cont = tok.encode(&format!(" {choice}"));
            let nll = engine.score_continuation(&prompt, &cont, mode)?;
            let mean_lp = -mean(&nll); // length-normalized logprob
            if mean_lp > best.0 {
                best = (mean_lp, ci);
            }
        }
        correct += (best.1 == s.label) as usize;
    }
    Ok(100.0 * correct as f64 / samples.len() as f64)
}

/// Language-modeling perplexity over held-out windows: prompt part P
/// selects experts, continuation part G is teacher-forced-scored under
/// the generation-phase weights (paper Fig. 5 protocol).
pub fn eval_lm_ppl(engine: &mut Engine, mode: Mode, n: usize, p: usize,
                   g: usize) -> Result<f64> {
    let windows = tasks::lm_windows(tasks::HELDOUT_SEED + 3, n, p + g);
    let mut total_nll = 0.0;
    let mut count = 0usize;
    for w in &windows {
        let nll =
            engine.score_continuation(&w[..p], &w[p..], mode)?;
        total_nll += nll.iter().sum::<f64>();
        count += nll.len();
    }
    Ok(eval::perplexity(total_nll, count))
}

// ---------------------------------------------------------------------------
// markdown table builder
// ---------------------------------------------------------------------------

pub struct MdTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl MdTable {
    pub fn new(header: &[&str]) -> Self {
        MdTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "| {} |", self.header.join(" | "));
        let _ = writeln!(
            s,
            "|{}|",
            self.header.iter().map(|_| "---").collect::<Vec<_>>()
                .join("|")
        );
        for r in &self.rows {
            let _ = writeln!(s, "| {} |", r.join(" | "));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn md_table_renders() {
        let mut t = MdTable::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("| a | b |"));
        assert!(s.contains("| 1 | 2 |"));
        assert_eq!(s.lines().count(), 3);
    }

    #[test]
    fn trim_generation_cuts_newline() {
        assert_eq!(trim_generation("abc\ndef"), "abc");
        assert_eq!(trim_generation("abc"), "abc");
        assert_eq!(trim_generation("\nabc"), "\nabc");
    }
}
