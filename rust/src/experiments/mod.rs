//! Experiment drivers: one per paper table/figure (DESIGN.md §5).
//! Each driver writes CSV (+ a markdown summary) into results/ and prints
//! the same rows the paper reports. EXPERIMENTS.md records the
//! paper-vs-measured comparison.

pub mod ablation;
pub mod common;
pub mod figures;
pub mod tables;

use anyhow::{bail, Result};

pub struct Experiment {
    pub id: &'static str,
    pub about: &'static str,
    pub run: fn(&crate::cli::Args) -> Result<()>,
}

pub fn registry() -> Vec<Experiment> {
    vec![
        Experiment { id: "fig1", about: "flocking heatmaps (relative FF activations)", run: figures::fig1 },
        Experiment { id: "fig2", about: "inter-sample Jaccard similarity of top-k expert sets", run: figures::fig2 },
        Experiment { id: "fig4", about: "relative performance vs FF sparsity sweep", run: figures::fig4 },
        Experiment { id: "fig5", about: "prompt length vs generation length PPL grid", run: figures::fig5 },
        Experiment { id: "fig6", about: "sorted selection statistic s per layer", run: figures::fig6 },
        Experiment { id: "fig7", about: "flocking under permuted / random inputs", run: figures::fig7 },
        Experiment { id: "table1", about: "classification accuracy at 50% FF sparsity", run: tables::table1 },
        Experiment { id: "table2", about: "generation tasks: full vs magnitude vs wanda vs griffin", run: tables::table2 },
        Experiment { id: "table3", about: "generation-phase latency (P+G setups)", run: tables::table3 },
        Experiment { id: "table4", about: "shared/batched expert selection (eq.7)", run: tables::table4 },
        Experiment { id: "table5", about: "expert selection strategies (top-k vs sampling)", run: tables::table5 },
        Experiment { id: "ablation-stat", about: "eq.6 relative statistic vs raw activation norms", run: ablation::ablation_stat },
        Experiment { id: "ablation-adaptive", about: "uniform vs layer-adaptive expert budgets (extension)", run: ablation::ablation_adaptive },
        Experiment { id: "adaptive-frontier", about: "quality-vs-speed frontier: uniform vs adaptive-layer keep at matched FLOP budgets", run: ablation::adaptive_frontier },
    ]
}

pub fn run(id: &str, args: &crate::cli::Args) -> Result<()> {
    if id == "all" {
        for e in registry() {
            println!("\n=== {} — {} ===", e.id, e.about);
            (e.run)(args)?;
        }
        return Ok(());
    }
    for e in registry() {
        if e.id == id {
            return (e.run)(args);
        }
    }
    bail!("unknown experiment {id:?}; have {:?} or 'all'",
          registry().iter().map(|e| e.id).collect::<Vec<_>>())
}
