//! Table drivers (paper Tables 1-5). Markdown + CSV into results/.

use std::fmt::Write as _;
use std::time::Instant;

use anyhow::Result;

use crate::cli::Args;
use crate::coordinator::engine::{Engine, Mode, PrefillLogits};
use crate::coordinator::selection::{self, Strategy};
use crate::coordinator::sequence::GenRequest;
use crate::experiments::common::{self, engine_auto, write_results, MdTable};
use crate::tokenizer::Tokenizer;
use crate::workload::{tasks, trace};

fn quality_models(args: &Args) -> Vec<String> {
    match args.get("model") {
        Some(m) => vec![m.to_string()],
        None => {
            // quality-table zoo: tiny/small configs (base/wide are for
            // latency + the e2e example; run them explicitly via --model)
            let mut v: Vec<String> = common::available_configs()
                .into_iter()
                .filter(|c| c.starts_with("tiny") || c.starts_with("small"))
                .collect();
            v.sort_by_key(|c| (!c.starts_with("small"), c.clone()));
            v
        }
    }
}

/// Table 1: classification accuracy at 50% FF sparsity —
/// Full vs Magnitude vs GRIFFIN across the model zoo, on three
/// multiple-choice variants (2/3/4 choices ↔ easier/harder tasks).
pub fn table1(args: &Args) -> Result<()> {
    let n = args.usize_or("samples", 16)?;
    let mut md = MdTable::new(&[
        "Model", "Method", "MC-2 acc", "MC-3 acc", "MC-4 acc",
    ]);
    let mut csv = String::from("model,method,mc2,mc3,mc4\n");
    for model in quality_models(args) {
        let mut engine = engine_auto(&model)?;
        for (label, mode) in [
            ("full", Mode::Full),
            ("magnitude", Mode::Magnitude { keep: 0.5 }),
            ("griffin", Mode::griffin(0.5)),
        ] {
            let mut cells = vec![model.clone(), label.to_string()];
            let mut row = format!("{model},{label}");
            for nc in [2usize, 3, 4] {
                let acc = common::eval_classification(
                    &mut engine, mode, n, nc)?;
                cells.push(format!("{acc:.1}"));
                let _ = write!(row, ",{acc:.2}");
            }
            println!("{model:>14} {label:>10}: {} {} {}",
                     cells[2], cells[3], cells[4]);
            md.row(cells);
            csv.push_str(&row);
            csv.push('\n');
        }
    }
    write_results("table1_classification.csv", &csv)?;
    write_results("table1_classification.md", &md.render())
}

/// Table 2: generation tasks — Full vs Magnitude vs Adaptive-Wanda vs
/// GRIFFIN at 50% FF sparsity; summarization (ROUGE-1/2/L) + QA (F1/EM).
pub fn table2(args: &Args) -> Result<()> {
    let n = args.usize_or("samples", 16)?;
    let mut md = MdTable::new(&[
        "Model", "Method", "Sum R-1", "Sum R-2", "Sum R-L", "QA F1",
        "QA EM",
    ]);
    let mut csv =
        String::from("model,method,rouge1,rouge2,rougel,qa_f1,qa_em\n");
    for model in quality_models(args) {
        let mut engine = engine_auto(&model)?;
        for (label, mode) in [
            ("full", Mode::Full),
            ("magnitude", Mode::Magnitude { keep: 0.5 }),
            ("wanda", Mode::Wanda { keep: 0.5 }),
            ("griffin", Mode::griffin(0.5)),
        ] {
            let r = common::eval_summarization(&mut engine, mode, n, 48)?;
            let (f1, em) = common::eval_qa(&mut engine, mode, n)?;
            println!(
                "{model:>14} {label:>10}: R1 {:.2} R2 {:.2} RL {:.2} \
                 F1 {f1:.2} EM {em:.2}",
                r.rouge1, r.rouge2, r.rougel
            );
            md.row(vec![
                model.clone(),
                label.to_string(),
                format!("{:.2}", r.rouge1),
                format!("{:.2}", r.rouge2),
                format!("{:.2}", r.rougel),
                format!("{f1:.2}"),
                format!("{em:.2}"),
            ]);
            let _ = writeln!(
                csv,
                "{model},{label},{:.3},{:.3},{:.3},{f1:.3},{em:.3}",
                r.rouge1, r.rouge2, r.rougel
            );
        }
    }
    write_results("table2_generation.csv", &csv)?;
    write_results("table2_generation.md", &md.render())
}

/// Table 3: generation-phase latency for "P + G" setups — full model vs
/// magnitude-pruned vs GRIFFIN at 50% / 75% FF sparsity (plus prompt
/// latency), mirroring the paper's layout. CPU-PJRT absolute numbers;
/// the paper's claim shape is the *ratio* GRIFFIN ≈ magnitude < full.
pub fn table3(args: &Args) -> Result<()> {
    // default to the FF-dominated config (DESIGN.md §2) — tiny/small are
    // attention-dominated and would understate the structured speedup
    let model = args.get_or("model", "wide-swiglu").to_string();
    let mut engine = engine_auto(&model)?;
    let cfg = engine.config().clone();
    let reps = args.usize_or("reps", 3)?;

    let p = args.usize_or("prompt", 256).map(|p| p.min(cfg.max_seq / 2))?;
    let gens = [cfg.max_seq / 8, cfg.max_seq / 2 - 1];

    let mut md = MdTable::new(&[
        "Setup", "Prompt (s)", "Full (s)", "Magnitude 50%/75%",
        "GRIFFIN 50%/75%",
    ]);
    let mut csv = String::from(
        "setup,prompt_s,full_s,mag50_s,mag75_s,grif50_s,grif75_s\n");

    for &g in &gens {
        let reqs = trace::generate(&trace::TraceSpec {
            seed: 11,
            n_requests: reps,
            prompt_len: p,
            gen_len: g,
            mean_gap_ms: 0,
            mixed_lengths: false,
            mix: trace::OpMix::default(),
        });
        let mut prompt_s = 0.0;
        let mut time_mode = |mode: Mode, engine: &mut Engine|
                             -> Result<f64> {
            // warmup: compile the mode's executables outside the timing
            let warm = GenRequest {
                id: 0,
                prompt: reqs[0].prompt.clone(),
                max_new_tokens: 2,
                mode,
                sampler: crate::sampling::SamplerSpec::Greedy,
                seed: 1,
                stop_at_eos: false,
                session: None,
                keep_requested: None,
                speculative: None,
                admitted_at: std::time::Instant::now(),
            };
            engine.generate(&warm)?;
            let mut total = 0.0;
            for r in &reqs {
                let req = GenRequest {
                    id: 0,
                    prompt: r.prompt.clone(),
                    max_new_tokens: r.max_new_tokens,
                    mode,
                    sampler: crate::sampling::SamplerSpec::Greedy,
                    seed: 1,
                    stop_at_eos: false,
                    session: None,
                    keep_requested: None,
                    speculative: None,
                    admitted_at: std::time::Instant::now(),
                };
                let resp = engine.generate(&req)?;
                total += resp.decode_ms / 1e3;
                prompt_s = resp.prefill_ms / 1e3;
            }
            Ok(total / reps as f64)
        };
        let full = time_mode(Mode::Full, &mut engine)?;
        let m50 = time_mode(Mode::Magnitude { keep: 0.5 }, &mut engine)?;
        let m75 = time_mode(Mode::Magnitude { keep: 0.25 }, &mut engine)?;
        let g50 = time_mode(Mode::griffin(0.5), &mut engine)?;
        let g75 = time_mode(Mode::griffin(0.25), &mut engine)?;
        let setup = format!("{p}+{g}");
        println!(
            "{setup:>10}: prompt {prompt_s:.2}s full {full:.2}s \
             mag {m50:.2}/{m75:.2}s griffin {g50:.2}/{g75:.2}s \
             (griffin speedup {:.2}x)",
            full / g50
        );
        md.row(vec![
            setup.clone(),
            format!("{prompt_s:.2}"),
            format!("{full:.2}"),
            format!("{m50:.2} / {m75:.2}"),
            format!("{g50:.2} / {g75:.2}"),
        ]);
        let _ = writeln!(
            csv,
            "{setup},{prompt_s:.4},{full:.4},{m50:.4},{m75:.4},\
             {g50:.4},{g75:.4}"
        );
    }
    write_results(&format!("table3_latency_{model}.csv"), &csv)?;
    write_results(&format!("table3_latency_{model}.md"), &md.render())
}

/// Table 4: sharing selected FF neurons — Full vs Shot (one sample's
/// experts reused), Global (eq.7 over the dataset), GRIFFIN batch 1/4/16.
pub fn table4(args: &Args) -> Result<()> {
    let model = args.get_or("model", "small-swiglu").to_string();
    let mut engine = engine_auto(&model)?;
    let n = args.usize_or("samples", 16)?;
    let tok = Tokenizer::new();
    let samples = tasks::summarization(tasks::HELDOUT_SEED, n, 14);

    // helper: ROUGE-1 with a FIXED expert set for all samples
    let eval_fixed = |engine: &mut Engine, idx: &[Vec<i32>]|
                          -> Result<f64> {
        let pruned = engine.gather(idx)?;
        let _ = &pruned;
        let mut r1 = 0.0;
        for s in &samples {
            // run GRIFFIN-like generation but with the fixed experts:
            // prefill full, then decode pruned with our own idx.
            let prompt = tok.encode_with_bos(&s.prompt);
            let mut pre =
                engine.prefill(std::slice::from_ref(&prompt),
                               PrefillLogits::LastToken)?;
            let pruned = engine.gather(idx)?;
            let first =
                crate::sampling::argmax(&pre.last_logits[0]) as i32;
            let mut toks = vec![first];
            let mut cur = vec![first; pre.state.batch];
            for _ in 1..48 {
                let logits = engine.decode_step(
                    &mut pre.state, &cur, Some(&pruned), None)?;
                let v = engine.config().vocab_size;
                let t = crate::sampling::argmax(&logits[..v]) as i32;
                toks.push(t);
                cur[0] = t;
            }
            let text = engine.tokenizer.decode(&toks);
            let cut = text.find('\n').unwrap_or(text.len());
            r1 += crate::eval::rouge_n(&text[..cut], &s.reference, 1).f1;
        }
        Ok(100.0 * r1 / samples.len() as f64)
    };

    // Full + per-sample GRIFFIN via the normal engine paths
    let full = common::eval_summarization(&mut engine, Mode::Full, n, 48)?
        .rouge1;

    // Shot: experts from the FIRST sample only
    let first_prompt = tok.encode_with_bos(&samples[0].prompt);
    let pre0 =
        engine.prefill(std::slice::from_ref(&first_prompt),
                       PrefillLogits::LastToken)?;
    let shot_idx = engine.select(&pre0.stats[0], 0.5, Strategy::TopK)?;
    let shot = eval_fixed(&mut engine, &shot_idx)?;

    // Global: eq.7 aggregate over ALL prompts
    let mut agg_in = Vec::new();
    for s in &samples {
        let prompt = tok.encode_with_bos(&s.prompt);
        let pre = engine.prefill(std::slice::from_ref(&prompt),
                                 PrefillLogits::LastToken)?;
        agg_in.push((pre.stats[0].clone(), prompt.len()));
    }
    let global_stats = selection::aggregate_stats(&agg_in);
    let global_idx = engine.select(&global_stats, 0.5, Strategy::TopK)?;
    let global = eval_fixed(&mut engine, &global_idx)?;

    // GRIFFIN batch sizes 1 / 4 / 16 (eq.7 within each batch)
    let mut griffin_at_batch = |b: usize| -> Result<f64> {
        let mut r1 = 0.0;
        let mut count = 0usize;
        for chunk in samples.chunks(b) {
            let reqs: Vec<GenRequest> = chunk
                .iter()
                .enumerate()
                .map(|(i, s)| GenRequest {
                    id: i as u64 + 1,
                    prompt: tok.encode_with_bos(&s.prompt),
                    max_new_tokens: 48,
                    mode: Mode::griffin(0.5),
                    sampler: crate::sampling::SamplerSpec::Greedy,
                    seed: 1,
                    stop_at_eos: false,
                    session: None,
                    keep_requested: None,
                    speculative: None,
                    admitted_at: std::time::Instant::now(),
                })
                .collect();
            let resps = engine.generate_batch(&reqs)?;
            for (resp, s) in resps.iter().zip(chunk) {
                let cut = resp.text.find('\n').unwrap_or(resp.text.len());
                r1 += crate::eval::rouge_n(&resp.text[..cut],
                                           &s.reference, 1).f1;
                count += 1;
            }
        }
        Ok(100.0 * r1 / count as f64)
    };
    let g1 = griffin_at_batch(1)?;
    let g4 = griffin_at_batch(4)?;
    let g16 = griffin_at_batch(16)?;

    println!(
        "full {full:.2} | shot {shot:.2} | global {global:.2} | \
         griffin(1) {g1:.2} | griffin(4) {g4:.2} | griffin(16) {g16:.2}"
    );
    let mut md = MdTable::new(&[
        "Model", "Full", "Shot", "Global", "GRIFFIN (1)", "GRIFFIN (4)",
        "GRIFFIN (16)",
    ]);
    md.row(vec![
        model.clone(),
        format!("{full:.2}"),
        format!("{shot:.2}"),
        format!("{global:.2}"),
        format!("{g1:.2}"),
        format!("{g4:.2}"),
        format!("{g16:.2}"),
    ]);
    let csv = format!(
        "model,full,shot,global,griffin1,griffin4,griffin16\n\
         {model},{full:.3},{shot:.3},{global:.3},{g1:.3},{g4:.3},{g16:.3}\n"
    );
    write_results("table4_batching.csv", &csv)?;
    write_results("table4_batching.md", &md.render())
}

/// Table 5 (appendix B): expert selection method ablation — top-k vs
/// weighted sampling vs topk+sampling at 50% sparsity.
pub fn table5(args: &Args) -> Result<()> {
    let model = args.get_or("model", "small-swiglu").to_string();
    let mut engine = engine_auto(&model)?;
    let n = args.usize_or("samples", 16)?;

    let mut md = MdTable::new(&[
        "Selection", "Sum R-1", "Sum R-2", "Sum R-L", "QA F1", "LM PPL",
    ]);
    let mut csv =
        String::from("selection,rouge1,rouge2,rougel,qa_f1,ppl\n");
    let full_ppl = common::eval_lm_ppl(&mut engine, Mode::Full, n, 96, 32)?;
    for (label, mode) in [
        ("full", Mode::Full),
        ("top-k", Mode::griffin(0.5)),
        ("sampling",
         Mode::Griffin { keep: 0.5, strategy: Strategy::Sampling { seed: 5 } }),
        ("topk+sampling",
         Mode::Griffin {
             keep: 0.5,
             strategy: Strategy::TopKPlusSampling { seed: 5 },
         }),
    ] {
        let r = common::eval_summarization(&mut engine, mode, n, 48)?;
        let (f1, _) = common::eval_qa(&mut engine, mode, n)?;
        let ppl = common::eval_lm_ppl(&mut engine, mode, n, 96, 32)?;
        println!(
            "{label:>14}: R1 {:.2} R2 {:.2} RL {:.2} F1 {f1:.2} \
             PPL {ppl:.3} (full {full_ppl:.3})",
            r.rouge1, r.rouge2, r.rougel
        );
        md.row(vec![
            label.to_string(),
            format!("{:.2}", r.rouge1),
            format!("{:.2}", r.rouge2),
            format!("{:.2}", r.rougel),
            format!("{f1:.2}"),
            format!("{ppl:.3}"),
        ]);
        let _ = writeln!(
            csv, "{label},{:.3},{:.3},{:.3},{f1:.3},{ppl:.4}",
            r.rouge1, r.rouge2, r.rougel
        );
    }
    write_results("table5_selection.csv", &csv)?;
    write_results("table5_selection.md", &md.render())?;
    let _ = Instant::now();
    Ok(())
}
