//! Figure drivers (paper Figs 1, 2, 4, 5, 6, 7). Each writes CSV into
//! results/ with exactly the series the paper plots.

use std::fmt::Write as _;

use anyhow::{Context, Result};

use crate::cli::Args;
use crate::coordinator::engine::{Engine, Mode, PrefillLogits};
use crate::eval;
use crate::experiments::common::{self, engine_auto, write_results};
use crate::runtime::{DeviceTensor, Substrate};
use crate::tokenizer::Tokenizer;
use crate::util::top_k_indices;
use crate::workload::{corpus, rng::XorShift64Star, tasks};

fn default_model(args: &Args) -> String {
    args.get_or("model", "small-swiglu").to_string()
}

/// Run the activations executable on a token sequence -> zbar [L][S][F].
fn activation_map(engine: &Engine, ids: &[i32])
                  -> Result<(Vec<f32>, usize, usize, usize)> {
    let spec = engine
        .session
        .manifest()
        .executables
        .values()
        .find(|e| e.kind == "activations")
        .context("no activations artifact (re-run make artifacts)")?
        .clone();
    let s_bucket = spec.seq.unwrap();
    let (row, real) = engine.tokenizer.fit(ids, s_bucket);
    let toks = engine.session.upload_i32(&[1, s_bucket], &row)?;
    let lens = engine.session.upload_i32(&[1], &[real as i32])?;
    let mut argv: Vec<&DeviceTensor> = engine.weights.ordered();
    argv.push(&toks);
    argv.push(&lens);
    let outs = engine.session.run(&spec.name, &argv)?;
    let cfg = engine.config();
    Ok((outs[0].to_f32()?, cfg.n_layers, s_bucket, cfg.d_ff))
}

fn zbar_csv(zbar: &[f32], layer: usize, s: usize, f: usize,
            max_rows: usize, max_cols: usize) -> String {
    let mut out = String::from("token,neuron,value\n");
    for t in 0..s.min(max_rows) {
        for j in 0..f.min(max_cols) {
            let v = zbar[(layer * s + t) * f + j];
            let _ = writeln!(out, "{t},{j},{v:.5}");
        }
    }
    out
}

/// Quantify flocking in one map: mean Jaccard between each token's
/// top-k(|zbar| row) set and the sequence-level top-k set. 1.0 = every
/// token shares the sequence's expert set (perfect vertical streaks).
pub fn flocking_score(zbar: &[f32], layer: usize, s_real: usize, s: usize,
                      f: usize, k: usize) -> f64 {
    // sequence-level stat: column l2 over tokens
    let mut col = vec![0f32; f];
    for t in 0..s_real {
        for j in 0..f {
            let v = zbar[(layer * s + t) * f + j];
            col[j] += v * v;
        }
    }
    let seq_set = top_k_indices(&col, k);
    let mut total = 0.0;
    for t in 0..s_real {
        let row = &zbar[(layer * s + t) * f..(layer * s + t) * f + f];
        let tok_set = top_k_indices(row, k);
        total += eval::jaccard(&tok_set, &seq_set);
    }
    total / s_real as f64
}

// ---------------------------------------------------------------------------

/// Fig 1: flocking heatmaps — relative FF activation magnitudes for a
/// held-out sequence; CSV per layer slice + per-layer flocking scores.
pub fn fig1(args: &Args) -> Result<()> {
    let model = default_model(args);
    let engine = engine_auto(&model)?;
    let tok = Tokenizer::new();
    let text = corpus::corpus(tasks::HELDOUT_SEED + 7, 4, 24);
    let ids = tok.encode(&text);
    let (zbar, l_n, s, f) = activation_map(&engine, &ids)?;
    let s_real = ids.len().min(s);

    let mid = l_n / 2;
    write_results(&format!("fig1_heatmap_{model}_layer{mid}.csv"),
                  &zbar_csv(&zbar, mid, s, f, 512, 512))?;

    let mut summary = String::from("layer,flocking_score@10%\n");
    let k = (f / 10).max(1);
    println!("flocking score (mean Jaccard of per-token vs sequence \
              top-{k} sets):");
    for l in 0..l_n {
        let score = flocking_score(&zbar, l, s_real, s, f, k);
        println!("  layer {l:2}: {score:.3}");
        let _ = writeln!(summary, "{l},{score:.4}");
    }
    write_results(&format!("fig1_flocking_scores_{model}.csv"), &summary)
}

/// Fig 2: mean pairwise Jaccard similarity between samples' top-k expert
/// sets, per layer, for a sweep of k fractions.
pub fn fig2(args: &Args) -> Result<()> {
    let model = default_model(args);
    let engine = engine_auto(&model)?;
    let n_samples = args.usize_or("samples", 16)?;
    let tok = Tokenizer::new();
    let cfg = engine.config().clone();

    // per-sample stats from prefill
    let windows = tasks::lm_windows(tasks::HELDOUT_SEED + 11, n_samples, 96);
    let mut per_sample = Vec::new();
    for w in &windows {
        let pre = engine.prefill(std::slice::from_ref(w),
                                 PrefillLogits::LastToken)?;
        per_sample.push(pre.stats[0].clone());
        let _ = tok;
    }

    let fracs = [0.05, 0.1, 0.2, 0.3, 0.5, 0.7, 0.9];
    let mut csv = String::from("layer,k_fraction,mean_jaccard\n");
    println!("layer x keep-fraction mean pairwise Jaccard:");
    for l in 0..cfg.n_layers {
        print!("  layer {l:2}:");
        for &frac in &fracs {
            let k = ((cfg.d_ff as f64 * frac) as usize).max(1);
            let sets: Vec<Vec<usize>> = per_sample
                .iter()
                .map(|stats| top_k_indices(&stats[l], k))
                .collect();
            let j = eval::mean_pairwise_jaccard(&sets);
            print!(" {frac:.2}:{j:.3}");
            let _ = writeln!(csv, "{l},{frac},{j:.4}");
        }
        println!();
    }
    write_results(&format!("fig2_jaccard_{model}.csv"), &csv)
}

/// Fig 4: relative performance vs FF sparsity (GRIFFIN / full ratio per
/// task across the keep-fraction sweep).
pub fn fig4(args: &Args) -> Result<()> {
    let model = default_model(args);
    let mut engine = engine_auto(&model)?;
    let n = args.usize_or("samples", 12)?;
    let cfg = engine.config().clone();

    // full-model baselines
    let full_ppl = common::eval_lm_ppl(&mut engine, Mode::Full, n, 96, 32)?;
    let full_rouge =
        common::eval_summarization(&mut engine, Mode::Full, n, 48)?;
    let (full_f1, _) = common::eval_qa(&mut engine, Mode::Full, n)?;
    let full_acc =
        common::eval_classification(&mut engine, Mode::Full, n, 4)?;

    let mut csv = String::from(
        "keep_fraction,k,ppl_ratio,rouge1_ratio,qa_f1_ratio,cls_acc_ratio\n",
    );
    println!("keep |   PPL-ratio  rouge1-ratio  qaF1-ratio  clsAcc-ratio");
    for &k in &cfg.keep_ks {
        if k >= cfg.d_ff {
            continue;
        }
        let keep = k as f64 / cfg.d_ff as f64;
        let mode = Mode::griffin(keep);
        let ppl = common::eval_lm_ppl(&mut engine, mode, n, 96, 32)?;
        let rouge = common::eval_summarization(&mut engine, mode, n, 48)?;
        let (f1, _) = common::eval_qa(&mut engine, mode, n)?;
        let acc = common::eval_classification(&mut engine, mode, n, 4)?;
        // for PPL lower is better: ratio = full/griffin so 1.0 = parity
        let rows = (
            full_ppl / ppl,
            rouge.rouge1 / full_rouge.rouge1.max(1e-9),
            f1 / full_f1.max(1e-9),
            acc / full_acc.max(1e-9),
        );
        println!(
            "{keep:.3} | {:>10.3} {:>12.3} {:>11.3} {:>12.3}",
            rows.0, rows.1, rows.2, rows.3
        );
        let _ = writeln!(
            csv,
            "{keep:.4},{k},{:.4},{:.4},{:.4},{:.4}",
            rows.0, rows.1, rows.2, rows.3
        );
    }
    write_results(&format!("fig4_sparsity_sweep_{model}.csv"), &csv)
}

/// Fig 5: prompt length vs generation length — PPL increase over the full
/// model on held-out text at 50% FF sparsity.
pub fn fig5(args: &Args) -> Result<()> {
    let model = default_model(args);
    let mut engine = engine_auto(&model)?;
    let n = args.usize_or("samples", 8)?;
    let grid_p = [16usize, 32, 64, 128];
    let grid_g = [16usize, 32, 64, 128];
    let mode = Mode::griffin(0.5);

    let mut csv = String::from("prompt_len,gen_len,ppl_full,ppl_griffin,\
                                ppl_increase\n");
    println!("P \\ G     " );
    for &p in &grid_p {
        for &g in &grid_g {
            if p + g > engine.config().max_seq {
                continue;
            }
            let full = common::eval_lm_ppl(&mut engine, Mode::Full,
                                           n, p, g)?;
            let grif = common::eval_lm_ppl(&mut engine, mode, n, p, g)?;
            let inc = grif - full;
            println!("P={p:<4} G={g:<4} full={full:>8.3} \
                      griffin={grif:>8.3} ΔPPL={inc:>7.3}");
            let _ = writeln!(csv,
                             "{p},{g},{full:.4},{grif:.4},{inc:.4}");
        }
    }
    write_results(&format!("fig5_prompt_vs_gen_{model}.csv"), &csv)
}

/// Fig 6: sorted entries of the statistic s per layer (normalized 0..1).
pub fn fig6(args: &Args) -> Result<()> {
    let model = default_model(args);
    let engine = engine_auto(&model)?;
    let w = tasks::lm_windows(tasks::HELDOUT_SEED + 13, 1, 96);
    let pre = engine.prefill(&w, PrefillLogits::LastToken)?;
    let stats = &pre.stats[0];

    let mut csv = String::from("layer,rank,value\n");
    for (l, s) in stats.iter().enumerate() {
        let mut v = s.clone();
        v.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let (lo, hi) = (v[v.len() - 1], v[0].max(1e-9));
        for (r, x) in v.iter().enumerate() {
            let norm = (x - lo) / (hi - lo).max(1e-9);
            let _ = writeln!(csv, "{l},{r},{norm:.5}");
        }
        // concentration summary: fraction of mass in the top 10%
        let total: f32 = v.iter().sum();
        let top: f32 = v[..v.len() / 10].iter().sum();
        println!("layer {l:2}: top-10% neurons hold {:.1}% of s mass",
                 100.0 * top / total.max(1e-9));
    }
    write_results(&format!("fig6_sorted_stat_{model}.csv"), &csv)
}

/// Fig 7: flocking under original vs permuted vs uniform-random token
/// sequences (appendix C): same activation-map pipeline as Fig 1, plus
/// the quantitative flocking score per input type.
pub fn fig7(args: &Args) -> Result<()> {
    let model = default_model(args);
    let engine = engine_auto(&model)?;
    let tok = Tokenizer::new();
    let text = corpus::corpus(tasks::HELDOUT_SEED + 17, 4, 24);
    let original = tok.encode(&text);
    let mut rng = XorShift64Star::new(99);
    let mut permuted = original.clone();
    rng.shuffle(&mut permuted);
    let random: Vec<i32> =
        (0..original.len()).map(|_| rng.below(256) as i32).collect();

    let cfg = engine.config().clone();
    let k = (cfg.d_ff / 10).max(1);
    let mut csv = String::from("input,layer,flocking_score@10%\n");
    for (name, ids) in [("original", &original), ("permuted", &permuted),
                        ("random", &random)] {
        let (zbar, l_n, s, f) = activation_map(&engine, ids)?;
        let s_real = ids.len().min(s);
        let mid = l_n / 2;
        write_results(
            &format!("fig7_heatmap_{model}_{name}_layer{mid}.csv"),
            &zbar_csv(&zbar, mid, s, f, 512, 512))?;
        print!("{name:>9}:");
        for l in 0..l_n {
            let score = flocking_score(&zbar, l, s_real, s, f, k);
            print!(" L{l}:{score:.3}");
            let _ = writeln!(csv, "{name},{l},{score:.4}");
        }
        println!();
    }
    write_results(&format!("fig7_flocking_scores_{model}.csv"), &csv)
}
