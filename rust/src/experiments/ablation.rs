//! Ablation: the selection-statistic design choice (DESIGN.md §6).
//!
//! Paper eq. 6 row-normalizes Z before taking column norms ("relative"
//! activations) — giving every token an equal vote. The obvious
//! alternative is the raw activation column norm ||Z_:,j|| (our prefill
//! already exports it as znorms for the Wanda baseline), where
//! high-magnitude tokens dominate. This driver quantifies the gap on
//! held-out LM scoring, which the paper asserts but does not plot.

use std::fmt::Write as _;

use anyhow::Result;

use crate::cli::Args;
use crate::coordinator::engine::{Mode, PrefillLogits};
use crate::coordinator::selection::Strategy;
use crate::eval;
use crate::experiments::common::{engine_auto, write_results};
use crate::runtime::Substrate;
use crate::workload::tasks;

/// Extension ablation: uniform per-layer k (paper) vs layer-adaptive
/// budgets under the same global expert count (selection.rs
/// adaptive_layer_allocation; motivated by the per-layer concentration
/// differences Fig. 6 shows). Teacher-forced LM PPL on held-out text.
pub fn ablation_adaptive(args: &Args) -> Result<()> {
    let model = args.get_or("model", "small-swiglu").to_string();
    let mut engine = engine_auto(&model)?;
    let n = args.usize_or("samples", 8)?;
    let (p, g) = (96usize, 48usize);
    let windows = tasks::lm_windows(tasks::HELDOUT_SEED + 29, n, p + g);
    let k_bucket = engine.k_for(0.5)?;
    let d_ff = engine.config().d_ff;

    let mut csv = String::from("mode,keep_avg,ppl\n");
    println!("uniform vs layer-adaptive budgets (LM PPL):");
    for keep in [0.3, 0.4, 0.5] {
        let k_avg = (d_ff as f64 * keep).round() as usize;
        if k_avg > k_bucket {
            continue;
        }
        let mut ppls = Vec::new();
        for adaptive in [false, true] {
            let mut nll_total = 0.0;
            let mut count = 0usize;
            for w in &windows {
                let mut pre = engine
                    .prefill(std::slice::from_ref(&w[..p].to_vec()),
             PrefillLogits::LastToken)?;
                let pruned = if adaptive {
                    engine.gather_adaptive(&pre.stats[0].clone(), keep)?
                } else {
                    // uniform: per-layer top-k_avg, padded to the same
                    // k_bucket executable (fair shape comparison)
                    let base = crate::coordinator::selection::
                        select_experts(
                            &pre.stats[0], k_avg,
                            crate::coordinator::selection::Strategy::TopK);
                    let mut idx = Vec::new();
                    let mut mask = Vec::new();
                    for layer in base {
                        let real = layer.len();
                        let pad = layer[0];
                        let mut l = layer;
                        l.resize(k_bucket, pad);
                        let mut m = vec![1.0f32; real];
                        m.resize(k_bucket, 0.0);
                        idx.push(l);
                        mask.push(m);
                    }
                    engine_gather_masked(&mut engine, &idx, &mask)?
                };
                let v = engine.config().vocab_size;
                nll_total += -crate::sampling::log_softmax_at(
                    &pre.last_logits[0], w[p] as usize) as f64;
                count += 1;
                let mut cur = vec![0i32; pre.state.batch];
                for i in p..p + g - 1 {
                    cur[0] = w[i];
                    let logits = engine.decode_step(
                        &mut pre.state, &cur, Some(&pruned), None)?;
                    nll_total += -crate::sampling::log_softmax_at(
                        &logits[..v], w[i + 1] as usize) as f64;
                    count += 1;
                }
            }
            let ppl = eval::perplexity(nll_total, count);
            ppls.push(ppl);
            let label = if adaptive { "adaptive" } else { "uniform" };
            let _ = writeln!(csv, "{label},{keep},{ppl:.4}");
        }
        println!("  keep_avg={keep}: uniform {:.3} | adaptive {:.3}",
                 ppls[0], ppls[1]);
    }
    write_results(&format!("ablation_adaptive_{model}.csv"), &csv)
}

/// Extension frontier: `adaptive-layer` vs uniform top-k at MATCHED
/// global FLOP budgets (the keep sweep's compiled buckets). Quality is
/// teacher-forced LM perplexity through the same serving path responses
/// take (`score_continuation`, so the adaptive arm runs the real ragged
/// executables); speed is greedy decode throughput at the same budget.
/// Together the rows trace the quality-vs-speed frontier the
/// adaptive-layer axis buys — at the sweep's floor and ceiling the two
/// strategies coincide by construction (no room to tilt), so their PPL
/// columns must match there.
pub fn adaptive_frontier(args: &Args) -> Result<()> {
    let model = args.get_or("model", "small-swiglu").to_string();
    let mut engine = engine_auto(&model)?;
    let n = args.usize_or("samples", 8)?;
    let gen_len = args.usize_or("gen", 32)?;
    let (p, g) = (96usize, 48usize);
    let windows = tasks::lm_windows(tasks::HELDOUT_SEED + 31, n, p + g);

    let mut csv =
        String::from("strategy,keep,k_used,k_per_layer,ppl,toks_per_sec\n");
    println!("adaptive-layer vs uniform keep at matched FLOP budgets:");
    for keep in [0.25, 0.5, 0.75, 1.0] {
        let mut row = Vec::new();
        for strategy in [Strategy::TopK, Strategy::AdaptiveLayer] {
            let mode = Mode::Griffin { keep, strategy };
            let mut nll_total = 0.0;
            let mut count = 0usize;
            for w in &windows {
                let nll =
                    engine.score_continuation(&w[..p], &w[p..], mode)?;
                nll_total += nll.iter().sum::<f64>();
                count += nll.len();
            }
            let ppl = eval::perplexity(nll_total, count);
            let mut req = crate::coordinator::sequence::GenRequest::greedy(
                0, windows[0][..p].to_vec(), gen_len, mode);
            req.stop_at_eos = false;
            let resp = engine.generate(&req)?;
            let label = match strategy {
                Strategy::AdaptiveLayer => "adaptive-layer",
                _ => "uniform",
            };
            let widths = resp
                .k_per_layer
                .as_ref()
                .map(|v| {
                    v.iter()
                        .map(|k| k.to_string())
                        .collect::<Vec<_>>()
                        .join("x")
                })
                .unwrap_or_default();
            let _ = writeln!(
                csv, "{label},{keep},{},{widths},{ppl:.4},{:.1}",
                resp.k_used.unwrap_or(0), resp.tokens_per_sec);
            row.push((label, ppl, resp.tokens_per_sec));
        }
        println!(
            "  keep={keep}: uniform ppl {:.3} ({:.0} tok/s) | \
             adaptive ppl {:.3} ({:.0} tok/s)",
            row[0].1, row[0].2, row[1].1, row[1].2);
    }
    write_results(&format!("adaptive_frontier_{model}.csv"), &csv)
}

/// Run the masked gather executable with explicit idx/mask (helper for
/// the uniform arm of the adaptive ablation).
fn engine_gather_masked(
    engine: &mut crate::coordinator::engine::Engine,
    idx: &[Vec<i32>],
    mask: &[Vec<f32>],
) -> Result<crate::coordinator::engine::PrunedWeights> {
    let cfg = engine.config().clone();
    let k = idx[0].len();
    let name = format!("gather_masked_k{k}");
    let flat_idx: Vec<i32> = idx.iter().flatten().copied().collect();
    let flat_mask: Vec<f32> = mask.iter().flatten().copied().collect();
    let idx_dev = engine.session.upload_i32(&[cfg.n_layers, k], &flat_idx)?;
    let mask_dev =
        engine.session.upload_f32(&[cfg.n_layers, k], &flat_mask)?;
    let mut args: Vec<&crate::runtime::DeviceTensor> =
        vec![engine.weights.get("w1"), engine.weights.get("w2")];
    if cfg.is_glu {
        args.push(engine.weights.get("wg"));
    }
    args.push(&idx_dev);
    args.push(&mask_dev);
    let outs = engine.session.run(&name, &args)?;
    Ok(engine.make_pruned(outs, k))
}

pub fn ablation_stat(args: &Args) -> Result<()> {
    let model = args.get_or("model", "small-swiglu").to_string();
    let mut engine = engine_auto(&model)?;
    let n = args.usize_or("samples", 8)?;
    let (p, g) = (96usize, 48usize);
    let windows = tasks::lm_windows(tasks::HELDOUT_SEED + 23, n, p + g);

    let mut csv = String::from("metric,keep,ppl\n");
    println!("selection metric ablation (LM PPL, lower is better):");
    for keep in [0.25, 0.5] {
        let k = engine.k_for(keep)?;
        let mut ppl = std::collections::BTreeMap::new();
        for metric in ["eq6_relative", "raw_znorm", "full"] {
            let mut nll_total = 0.0;
            let mut count = 0usize;
            for w in &windows {
                if metric == "full" {
                    let v = engine.score_continuation(
                        &w[..p], &w[p..], Mode::Full)?;
                    nll_total += v.iter().sum::<f64>();
                    count += v.len();
                    continue;
                }
                let mut pre = engine
                    .prefill(std::slice::from_ref(&w[..p].to_vec()),
             PrefillLogits::LastToken)?;
                let stats = if metric == "eq6_relative" {
                    &pre.stats[0]
                } else {
                    &pre.znorms[0]
                };
                let idx = crate::coordinator::selection::select_experts(
                    stats, k, Strategy::TopK);
                let pruned = engine.gather(&idx)?;
                // teacher-forced scoring under the pruned weights
                let v = engine.config().vocab_size;
                nll_total += -crate::sampling::log_softmax_at(
                    &pre.last_logits[0], w[p] as usize)
                    as f64;
                count += 1;
                let mut cur = vec![0i32; pre.state.batch];
                for i in p..p + g - 1 {
                    cur[0] = w[i];
                    let logits = engine.decode_step(
                        &mut pre.state, &cur, Some(&pruned), None)?;
                    nll_total += -crate::sampling::log_softmax_at(
                        &logits[..v], w[i + 1] as usize)
                        as f64;
                    count += 1;
                }
            }
            ppl.insert(metric, eval::perplexity(nll_total, count));
        }
        println!(
            "  keep={keep}: full {:.3} | eq6 {:.3} | raw-znorm {:.3}",
            ppl["full"], ppl["eq6_relative"], ppl["raw_znorm"]
        );
        for (m, v) in &ppl {
            let _ = writeln!(csv, "{m},{keep},{v:.4}");
        }
    }
    write_results(&format!("ablation_stat_{model}.csv"), &csv)
}
