//! Evaluation metrics: ROUGE-1/2/L, token F1 / exact match, perplexity
//! helpers, and Jaccard similarity over expert sets (paper Fig. 2).
//!
//! These mirror the metrics of the paper's task suite (XSum/CNN-DM use
//! ROUGE, CoQA uses F1/EM, the WikiText ablations use perplexity).

use std::collections::{BTreeMap, BTreeSet};

/// Whitespace word tokenization with ascii lowercasing (standard for
/// rouge-style scoring of our ascii corpus).
pub fn words(text: &str) -> Vec<String> {
    text.split_whitespace()
        .map(|w| {
            w.chars()
                .filter(|c| c.is_ascii_alphanumeric())
                .collect::<String>()
                .to_ascii_lowercase()
        })
        .filter(|w| !w.is_empty())
        .collect()
}

fn counts(ws: &[String]) -> BTreeMap<&str, usize> {
    let mut m = BTreeMap::new();
    for w in ws {
        *m.entry(w.as_str()).or_insert(0) += 1;
    }
    m
}

fn overlap(a: &[String], b: &[String]) -> usize {
    let ca = counts(a);
    let cb = counts(b);
    ca.iter()
        .map(|(w, n)| n.min(cb.get(w).unwrap_or(&0)))
        .sum()
}

#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct PRF {
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
}

fn prf(match_count: usize, cand_len: usize, ref_len: usize) -> PRF {
    if cand_len == 0 || ref_len == 0 || match_count == 0 {
        return PRF::default();
    }
    let p = match_count as f64 / cand_len as f64;
    let r = match_count as f64 / ref_len as f64;
    PRF { precision: p, recall: r, f1: 2.0 * p * r / (p + r) }
}

/// ROUGE-N for n = 1 or 2 (f1 of n-gram overlap).
pub fn rouge_n(candidate: &str, reference: &str, n: usize) -> PRF {
    let cw = words(candidate);
    let rw = words(reference);
    if cw.len() < n || rw.len() < n {
        return PRF::default();
    }
    let grams = |ws: &[String]| -> Vec<String> {
        ws.windows(n).map(|w| w.join(" ")).collect()
    };
    let cg = grams(&cw);
    let rg = grams(&rw);
    prf(overlap(&cg, &rg), cg.len(), rg.len())
}

/// ROUGE-L (f1 over longest common subsequence of words).
pub fn rouge_l(candidate: &str, reference: &str) -> PRF {
    let cw = words(candidate);
    let rw = words(reference);
    let l = lcs_len(&cw, &rw);
    prf(l, cw.len(), rw.len())
}

fn lcs_len(a: &[String], b: &[String]) -> usize {
    if a.is_empty() || b.is_empty() {
        return 0;
    }
    let mut prev = vec![0usize; b.len() + 1];
    let mut cur = vec![0usize; b.len() + 1];
    for i in 1..=a.len() {
        for j in 1..=b.len() {
            cur[j] = if a[i - 1] == b[j - 1] {
                prev[j - 1] + 1
            } else {
                prev[j].max(cur[j - 1])
            };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct RougeScores {
    pub rouge1: f64,
    pub rouge2: f64,
    pub rougel: f64,
}

pub fn rouge_all(candidate: &str, reference: &str) -> RougeScores {
    RougeScores {
        rouge1: rouge_n(candidate, reference, 1).f1,
        rouge2: rouge_n(candidate, reference, 2).f1,
        rougel: rouge_l(candidate, reference).f1,
    }
}

/// SQuAD-style token F1 (CoQA metric).
pub fn token_f1(candidate: &str, reference: &str) -> f64 {
    let cw = words(candidate);
    let rw = words(reference);
    prf(overlap(&cw, &rw), cw.len(), rw.len()).f1
}

/// Exact match after normalization.
pub fn exact_match(candidate: &str, reference: &str) -> bool {
    words(candidate) == words(reference)
}

/// Jaccard similarity of two index sets (paper Fig. 2: similarity of
/// top-k expert sets between sequences).
pub fn jaccard(a: &[usize], b: &[usize]) -> f64 {
    let sa: BTreeSet<_> = a.iter().collect();
    let sb: BTreeSet<_> = b.iter().collect();
    let inter = sa.intersection(&sb).count();
    let union = sa.union(&sb).count();
    if union == 0 {
        1.0
    } else {
        inter as f64 / union as f64
    }
}

/// Mean pairwise Jaccard similarity over many sets.
pub fn mean_pairwise_jaccard(sets: &[Vec<usize>]) -> f64 {
    let mut total = 0.0;
    let mut pairs = 0usize;
    for i in 0..sets.len() {
        for j in (i + 1)..sets.len() {
            total += jaccard(&sets[i], &sets[j]);
            pairs += 1;
        }
    }
    if pairs == 0 {
        1.0
    } else {
        total / pairs as f64
    }
}

/// Perplexity from summed negative log-likelihood over `n` tokens.
pub fn perplexity(total_nll: f64, n: usize) -> f64 {
    if n == 0 {
        f64::NAN
    } else {
        (total_nll / n as f64).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn words_normalizes() {
        assert_eq!(words("The quick, BROWN fox!"),
                   vec!["the", "quick", "brown", "fox"]);
        assert_eq!(words("  "), Vec::<String>::new());
    }

    #[test]
    fn rouge1_identical_is_one() {
        let s = "the river joins the lake";
        let r = rouge_n(s, s, 1);
        assert!((r.f1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rouge1_disjoint_is_zero() {
        assert_eq!(rouge_n("aa bb", "cc dd", 1).f1, 0.0);
    }

    #[test]
    fn rouge1_known_value() {
        // cand: "the cat sat", ref: "the cat ate fish"
        // overlap = 2 (the, cat); p = 2/3, r = 2/4 -> f1 = 4/7
        let r = rouge_n("the cat sat", "the cat ate fish", 1);
        assert!((r.f1 - 4.0 / 7.0).abs() < 1e-12, "{r:?}");
    }

    #[test]
    fn rouge2_bigram_overlap() {
        // shared bigram: "the cat"
        let r = rouge_n("the cat sat", "the cat ate", 2);
        // cand bigrams: [the cat, cat sat]; ref: [the cat, cat ate]
        // overlap 1; p = r = 1/2 -> f1 = 1/2
        assert!((r.f1 - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rouge_l_subsequence() {
        // LCS("a b c d", "a x c d") = [a c d] = 3; p=r=3/4
        let r = rouge_l("a b c d", "a x c d");
        assert!((r.f1 - 0.75).abs() < 1e-12);
    }

    #[test]
    fn rouge_multiset_clipping() {
        // candidate repeats "the" 5x but reference has it twice
        let r = rouge_n("the the the the the", "the lake the", 1);
        // overlap clipped to 2; p = 2/5, r = 2/3
        let expect = 2.0 * (2.0 / 5.0) * (2.0 / 3.0) / (2.0 / 5.0 + 2.0 / 3.0);
        assert!((r.f1 - expect).abs() < 1e-12);
    }

    #[test]
    fn f1_and_em() {
        assert_eq!(token_f1("the lake", "the lake"), 1.0);
        assert!(exact_match("The Lake!", "the lake"));
        assert!(!exact_match("the lake", "the river"));
        assert_eq!(token_f1("x y", "a b"), 0.0);
    }

    #[test]
    fn jaccard_cases() {
        assert_eq!(jaccard(&[1, 2, 3], &[1, 2, 3]), 1.0);
        assert_eq!(jaccard(&[1, 2], &[3, 4]), 0.0);
        assert_eq!(jaccard(&[1, 2, 3], &[2, 3, 4]), 0.5);
        assert_eq!(jaccard(&[], &[]), 1.0);
    }

    #[test]
    fn mean_pairwise() {
        let sets = vec![vec![1, 2], vec![1, 2], vec![3, 4]];
        // pairs: (1.0, 0.0, 0.0) -> 1/3
        let m = mean_pairwise_jaccard(&sets);
        assert!((m - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn perplexity_of_uniform() {
        // NLL of uniform over 4 symbols = ln(4) per token -> PPL = 4
        let nll = (4.0f64).ln() * 10.0;
        assert!((perplexity(nll, 10) - 4.0).abs() < 1e-9);
        assert!(perplexity(0.0, 0).is_nan());
    }

    #[test]
    fn lcs_property_bounds() {
        let mut rng = crate::workload::rng::XorShift64Star::new(2);
        for _ in 0..50 {
            let gen = |rng: &mut crate::workload::rng::XorShift64Star| {
                let n = rng.below(8);
                (0..n)
                    .map(|_| format!("w{}", rng.below(4)))
                    .collect::<Vec<_>>()
            };
            let a = gen(&mut rng);
            let b = gen(&mut rng);
            let l = lcs_len(&a, &b);
            assert!(l <= a.len().min(b.len()));
            let l_self = lcs_len(&a, &a);
            assert_eq!(l_self, a.len());
        }
    }
}
