//! Serving metrics: latency histograms, counters, throughput meters.
//!
//! Lock-free-ish (a Mutex per histogram is fine at our request rates);
//! the engine exposes a `MetricsRegistry` snapshot over the server's
//! `metrics` endpoint and the bench harness prints the same numbers.
//!
//! Host-boundary accounting (`host_transfer_bytes` in the JSON
//! snapshot): `host_bytes_to_device` / `host_bytes_to_host` count every
//! byte the runtime stages across the PJRT host boundary. On the fused
//! decode path (`decode_sample_*`, on-device sampling) the per-step
//! downstream traffic is O(B) — token ids and logprobs — instead of the
//! O(B * vocab) logits download of the host sampling path; tests assert
//! the difference through these counters. `gather_cache` reports the
//! PrunedWeights reuse cache: `hits / (hits + misses)` is the fraction
//! of generation-phase weight rebuilds that skipped `gather_k{K}`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Log-bucketed latency histogram (microsecond resolution, ~7% buckets).
#[derive(Debug)]
pub struct Histogram {
    buckets: Mutex<Vec<u64>>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const BUCKETS: usize = 256;
/// bucket i covers [GROWTH^i, GROWTH^(i+1)) microseconds
const GROWTH: f64 = 1.07;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: Mutex::new(vec![0; BUCKETS]),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_for(us: u64) -> usize {
        if us == 0 {
            return 0;
        }
        let b = (us as f64).ln() / GROWTH.ln();
        (b as usize).min(BUCKETS - 1)
    }

    fn bucket_upper(i: usize) -> f64 {
        GROWTH.powi(i as i32 + 1)
    }

    pub fn record(&self, d: Duration) {
        self.record_value(d.as_micros() as u64);
    }

    /// Record a raw value. The histogram is unit-agnostic: latency
    /// histograms store microseconds, the slot-occupancy histogram stores
    /// occupied-slot counts per decode tick.
    pub fn record_value(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(v, Ordering::Relaxed);
        self.max_us.fetch_max(v, Ordering::Relaxed);
        let mut b = self.buckets.lock().unwrap();
        b[Self::bucket_for(v)] += 1;
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate percentile from the log buckets (upper bound of the
    /// bucket containing the rank).
    pub fn percentile_us(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = (p / 100.0 * total as f64).ceil() as u64;
        let b = self.buckets.lock().unwrap();
        let mut seen = 0u64;
        for (i, &c) in b.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_upper(i);
            }
        }
        Self::bucket_upper(BUCKETS - 1)
    }

    /// Merge another histogram's samples into this one (fleet rollups:
    /// log buckets are position-aligned, so bucket-wise addition is an
    /// exact merge).
    pub fn absorb(&self, other: &Histogram) {
        let theirs = other.buckets.lock().unwrap().clone();
        {
            let mut b = self.buckets.lock().unwrap();
            for (i, c) in theirs.iter().enumerate() {
                b[i] += c;
            }
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed),
                       Ordering::Relaxed);
        self.sum_us
            .fetch_add(other.sum_us.load(Ordering::Relaxed),
                       Ordering::Relaxed);
        self.max_us
            .fetch_max(other.max_us.load(Ordering::Relaxed),
                       Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            count: self.count(),
            mean_us: self.mean_us(),
            p50_us: self.percentile_us(50.0),
            p90_us: self.percentile_us(90.0),
            p99_us: self.percentile_us(99.0),
            max_us: self.max_us(),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p90_us: f64,
    pub p99_us: f64,
    pub max_us: u64,
}

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1)
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (slot occupancy, pool size).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Tokens/sec style meter.
#[derive(Debug)]
pub struct Meter {
    start: Instant,
    events: Counter,
}

impl Default for Meter {
    fn default() -> Self {
        Meter { start: Instant::now(), events: Counter::default() }
    }
}

impl Meter {
    pub fn add(&self, n: u64) {
        self.events.add(n)
    }
    pub fn rate_per_sec(&self) -> f64 {
        let dt = self.start.elapsed().as_secs_f64();
        if dt <= 0.0 {
            0.0
        } else {
            self.events.get() as f64 / dt
        }
    }
    pub fn total(&self) -> u64 {
        self.events.get()
    }
}

/// All serving metrics in one place.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    pub prefill_latency: Histogram,
    pub decode_step_latency: Histogram,
    pub selection_latency: Histogram,
    pub gather_latency: Histogram,
    pub kv_splice_latency: Histogram,
    pub e2e_latency: Histogram,
    pub queue_wait: Histogram,
    /// admission → first streamed token, per request
    pub ttft: Histogram,
    /// gap between consecutive streamed tokens of one sequence
    pub inter_token_latency: Histogram,
    /// occupied-slot count per decode tick (values, not latencies)
    pub slot_occupancy: Histogram,
    pub requests_admitted: Counter,
    pub requests_completed: Counter,
    pub requests_rejected: Counter,
    /// requests retired with an `engine_error` event (per-slot fault
    /// containment: the serve loop survives, the request does not)
    pub requests_failed: Counter,
    /// requests stopped by an explicit cancel (or client disconnect)
    pub requests_cancelled: Counter,
    /// requests refused with a retryable `overloaded` error by the
    /// SLO-aware admission controller's Shed stage
    pub requests_shed: Counter,
    /// prunable requests admitted with their keep fraction snapped down
    /// by the controller's Degrade stage (the degradation is audited in
    /// the response's `prune.keep_requested` provenance)
    pub requests_downkept: Counter,
    pub decode_ticks: Counter,
    /// decode ticks served by the fused decode_sample_* path (on-device
    /// sampling; no [B, vocab] logits download)
    pub fused_decode_ticks: Counter,
    /// admission prefills served by the reduced prefill_sample_* path
    /// (last-token logits + on-device first-token sampling; no [B, S,
    /// vocab] logits download). Incremented once per admission batch.
    pub fused_admissions: Counter,
    /// KV admission splices served by the compiled splice_b{src}_b{dst}
    /// executables (no host-side KV round trip)
    pub fused_splices: Counter,
    /// host-boundary bytes attributable to ADMISSION work (prefill +
    /// KV splice), metered by the scheduler as to_device/to_host deltas
    /// around its admission block — the quantity the device-resident
    /// admission path exists to shrink (tests and bench_serving assert
    /// on these)
    pub admission_bytes_to_device: Counter,
    pub admission_bytes_to_host: Counter,
    /// bytes staged host -> device (uploads: tokens/pos, prompt
    /// matrices, KV splices, gathered-index vectors, weight sets)
    pub host_bytes_to_device: Counter,
    /// bytes copied device -> host (downloads: logits on the host
    /// sampling path, sampled token ids + logprobs on the fused path,
    /// prefill stats, KV splice staging). The fused decode path exists
    /// to keep this O(B) per step instead of O(B * vocab).
    pub host_bytes_to_host: Counter,
    /// PrunedWeights reuse cache (Engine::gather_cached): hits are
    /// decode-weight rebuilds served without running gather_k{K}
    pub gather_cache_hits: Counter,
    pub gather_cache_misses: Counter,
    /// speculative decode ticks (draft → verify → accept; one per
    /// verify_b{B}_s{D} dispatch). Plain decode ticks taken as spec
    /// fallback still count only in `decode_ticks`.
    pub spec_ticks: Counter,
    /// draft tokens proposed by the pruned drafter across all slots
    /// (D-1 per slot per spec tick)
    pub draft_tokens_proposed: Counter,
    /// draft tokens whose full-model verification matched the slot
    /// sampler's decision (accepted = emitted without a correction)
    pub draft_tokens_accepted: Counter,
    /// per-slot acceptance rate per spec tick, in percent (a value
    /// histogram like slot_occupancy, not a latency)
    pub spec_acceptance_pct: Histogram,
    /// latency of the verify_b{B}_s{D} full-model dispatch
    pub verify_latency: Histogram,
    /// prefix cache: admissions whose prompt matched a cached
    /// block-aligned prefix (the KV rows + flocking statistics were
    /// spliced from the cache instead of prefilled)
    pub prefix_cache_hits: Counter,
    /// cache-consulting admissions that found no usable prefix
    pub prefix_cache_misses: Counter,
    /// block-aligned prefix snapshots published into the cache
    pub prefix_cache_inserts: Counter,
    /// entries dropped by the byte-budget LRU (never a live-ref entry)
    pub prefix_cache_evictions: Counter,
    /// prompt tokens restored from cached prefixes (not prefilled —
    /// compare against `prompt_tokens`, which counts only real prefill)
    pub prefix_tokens_reused: Counter,
    /// prefill FLOP-traffic proxy actually avoided: token bytes of the
    /// reused prefixes that never crossed the host boundary again
    pub prefix_bytes_saved: Counter,
    /// payload bytes currently resident in the prefix cache
    pub prefix_cache_bytes: Gauge,
    pub slots_busy: Gauge,
    pub slots_total: Gauge,
    pub tokens_generated: Meter,
    pub prompt_tokens: Meter,
}

impl MetricsRegistry {
    /// Merge another registry's measurements into this one — the fleet
    /// rollup for sharded serving: histograms merge bucket-wise,
    /// counters and meter totals add, gauges sum (each shard owns its
    /// own slot pool).
    ///
    /// Caveat: a rollup registry is created at snapshot time, so its
    /// meters' elapsed clocks are ~0 and `rate_per_sec` on the rollup is
    /// meaningless. A fleet rate is the SUM of the per-shard
    /// `rate_per_sec` values (each measured against that shard's own
    /// start instant); `server::sharded` patches it into the JSON.
    pub fn absorb(&self, other: &MetricsRegistry) {
        self.prefill_latency.absorb(&other.prefill_latency);
        self.decode_step_latency.absorb(&other.decode_step_latency);
        self.selection_latency.absorb(&other.selection_latency);
        self.gather_latency.absorb(&other.gather_latency);
        self.kv_splice_latency.absorb(&other.kv_splice_latency);
        self.e2e_latency.absorb(&other.e2e_latency);
        self.queue_wait.absorb(&other.queue_wait);
        self.ttft.absorb(&other.ttft);
        self.inter_token_latency.absorb(&other.inter_token_latency);
        self.slot_occupancy.absorb(&other.slot_occupancy);
        self.requests_admitted.add(other.requests_admitted.get());
        self.requests_completed.add(other.requests_completed.get());
        self.requests_rejected.add(other.requests_rejected.get());
        self.requests_failed.add(other.requests_failed.get());
        self.requests_cancelled.add(other.requests_cancelled.get());
        self.requests_shed.add(other.requests_shed.get());
        self.requests_downkept.add(other.requests_downkept.get());
        self.decode_ticks.add(other.decode_ticks.get());
        self.fused_decode_ticks.add(other.fused_decode_ticks.get());
        self.fused_admissions.add(other.fused_admissions.get());
        self.fused_splices.add(other.fused_splices.get());
        self.admission_bytes_to_device
            .add(other.admission_bytes_to_device.get());
        self.admission_bytes_to_host
            .add(other.admission_bytes_to_host.get());
        self.host_bytes_to_device.add(other.host_bytes_to_device.get());
        self.host_bytes_to_host.add(other.host_bytes_to_host.get());
        self.gather_cache_hits.add(other.gather_cache_hits.get());
        self.gather_cache_misses.add(other.gather_cache_misses.get());
        self.spec_ticks.add(other.spec_ticks.get());
        self.draft_tokens_proposed.add(other.draft_tokens_proposed.get());
        self.draft_tokens_accepted.add(other.draft_tokens_accepted.get());
        self.spec_acceptance_pct.absorb(&other.spec_acceptance_pct);
        self.verify_latency.absorb(&other.verify_latency);
        self.prefix_cache_hits.add(other.prefix_cache_hits.get());
        self.prefix_cache_misses.add(other.prefix_cache_misses.get());
        self.prefix_cache_inserts.add(other.prefix_cache_inserts.get());
        self.prefix_cache_evictions
            .add(other.prefix_cache_evictions.get());
        self.prefix_tokens_reused.add(other.prefix_tokens_reused.get());
        self.prefix_bytes_saved.add(other.prefix_bytes_saved.get());
        self.prefix_cache_bytes.set(
            self.prefix_cache_bytes.get() + other.prefix_cache_bytes.get(),
        );
        self.slots_busy
            .set(self.slots_busy.get() + other.slots_busy.get());
        self.slots_total
            .set(self.slots_total.get() + other.slots_total.get());
        self.tokens_generated.add(other.tokens_generated.total());
        self.prompt_tokens.add(other.prompt_tokens.total());
    }

    pub fn to_json(&self) -> crate::json::Value {
        use crate::json::{n, obj, Value};
        let hist = |h: &Histogram| {
            let s = h.snapshot();
            obj(vec![
                ("count", n(s.count as f64)),
                ("mean_us", n(s.mean_us)),
                ("p50_us", n(s.p50_us)),
                ("p90_us", n(s.p90_us)),
                ("p99_us", n(s.p99_us)),
                ("max_us", n(s.max_us as f64)),
            ])
        };
        let occ = self.slot_occupancy.snapshot();
        obj(vec![
            ("prefill_latency", hist(&self.prefill_latency)),
            ("decode_step_latency", hist(&self.decode_step_latency)),
            ("selection_latency", hist(&self.selection_latency)),
            ("gather_latency", hist(&self.gather_latency)),
            ("kv_splice_latency", hist(&self.kv_splice_latency)),
            ("e2e_latency", hist(&self.e2e_latency)),
            ("queue_wait", hist(&self.queue_wait)),
            ("ttft", hist(&self.ttft)),
            ("inter_token_latency", hist(&self.inter_token_latency)),
            (
                "slot_occupancy",
                obj(vec![
                    ("ticks", n(occ.count as f64)),
                    ("mean", n(occ.mean_us)),
                    ("max", n(occ.max_us as f64)),
                    ("busy", n(self.slots_busy.get() as f64)),
                    ("total", n(self.slots_total.get() as f64)),
                ]),
            ),
            (
                "requests",
                obj(vec![
                    ("admitted", n(self.requests_admitted.get() as f64)),
                    ("completed", n(self.requests_completed.get() as f64)),
                    ("rejected", n(self.requests_rejected.get() as f64)),
                    ("failed", n(self.requests_failed.get() as f64)),
                    ("cancelled", n(self.requests_cancelled.get() as f64)),
                    ("shed", n(self.requests_shed.get() as f64)),
                    ("downkept", n(self.requests_downkept.get() as f64)),
                ]),
            ),
            (
                "throughput",
                obj(vec![
                    (
                        "tokens_per_sec",
                        n(self.tokens_generated.rate_per_sec()),
                    ),
                    (
                        "tokens_total",
                        Value::Num(self.tokens_generated.total() as f64),
                    ),
                    ("decode_ticks", n(self.decode_ticks.get() as f64)),
                    (
                        "fused_decode_ticks",
                        n(self.fused_decode_ticks.get() as f64),
                    ),
                    (
                        "fused_admissions",
                        n(self.fused_admissions.get() as f64),
                    ),
                    ("fused_splices", n(self.fused_splices.get() as f64)),
                ]),
            ),
            (
                "host_transfer_bytes",
                obj(vec![
                    (
                        "to_device",
                        n(self.host_bytes_to_device.get() as f64),
                    ),
                    ("to_host", n(self.host_bytes_to_host.get() as f64)),
                    (
                        "admission_to_device",
                        n(self.admission_bytes_to_device.get() as f64),
                    ),
                    (
                        "admission_to_host",
                        n(self.admission_bytes_to_host.get() as f64),
                    ),
                ]),
            ),
            (
                "gather_cache",
                obj(vec![
                    ("hits", n(self.gather_cache_hits.get() as f64)),
                    ("misses", n(self.gather_cache_misses.get() as f64)),
                ]),
            ),
            (
                "speculative",
                obj(vec![
                    ("spec_ticks", n(self.spec_ticks.get() as f64)),
                    (
                        "draft_tokens_proposed",
                        n(self.draft_tokens_proposed.get() as f64),
                    ),
                    (
                        "draft_tokens_accepted",
                        n(self.draft_tokens_accepted.get() as f64),
                    ),
                    (
                        "acceptance_pct",
                        hist(&self.spec_acceptance_pct),
                    ),
                    ("verify_latency", hist(&self.verify_latency)),
                ]),
            ),
            (
                "prefix_cache",
                obj(vec![
                    ("hits", n(self.prefix_cache_hits.get() as f64)),
                    ("misses", n(self.prefix_cache_misses.get() as f64)),
                    ("inserts", n(self.prefix_cache_inserts.get() as f64)),
                    (
                        "evictions",
                        n(self.prefix_cache_evictions.get() as f64),
                    ),
                    (
                        "prefix_tokens_reused",
                        n(self.prefix_tokens_reused.get() as f64),
                    ),
                    (
                        "bytes_saved",
                        n(self.prefix_bytes_saved.get() as f64),
                    ),
                    (
                        "resident_bytes",
                        n(self.prefix_cache_bytes.get() as f64),
                    ),
                ]),
            ),
        ])
    }
}

/// Simple scoped timer.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
    pub fn record_into(&self, h: &Histogram) {
        h.record(self.0.elapsed());
    }
}

/// Export a table of named snapshots as CSV rows.
pub fn histograms_csv(rows: &BTreeMap<String, HistogramSnapshot>) -> String {
    let mut out =
        String::from("name,count,mean_us,p50_us,p90_us,p99_us,max_us\n");
    for (name, s) in rows {
        out.push_str(&format!(
            "{},{},{:.1},{:.1},{:.1},{:.1},{}\n",
            name, s.count, s.mean_us, s.p50_us, s.p90_us, s.p99_us, s.max_us
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_basic() {
        let h = Histogram::new();
        for ms in [1u64, 2, 3, 4, 5] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_us() - 3000.0).abs() < 1.0);
        assert_eq!(h.max_us(), 5000);
        let p50 = h.percentile_us(50.0);
        assert!(p50 >= 2500.0 && p50 <= 3500.0, "p50 = {p50}");
    }

    #[test]
    fn histogram_percentile_monotone() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i * 17));
        }
        let (p50, p90, p99) = (
            h.percentile_us(50.0),
            h.percentile_us(90.0),
            h.percentile_us(99.0),
        );
        assert!(p50 <= p90 && p90 <= p99, "{p50} {p90} {p99}");
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::new();
        assert_eq!(h.percentile_us(99.0), 0.0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn counter_and_meter() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let m = Meter::default();
        m.add(100);
        assert_eq!(m.total(), 100);
        assert!(m.rate_per_sec() > 0.0);
    }

    #[test]
    fn registry_json_shape() {
        let r = MetricsRegistry::default();
        r.prefill_latency.record(Duration::from_millis(10));
        let v = r.to_json();
        assert!(v.get("prefill_latency").unwrap().get("count").is_some());
        assert!(v.get("requests").unwrap().get("failed").is_some());
        assert!(v.get("requests").unwrap().get("cancelled").is_some());
        assert!(v.get("throughput").is_some());
        assert!(v.get("ttft").is_some());
        assert!(v.get("inter_token_latency").is_some());
        assert!(v.get("slot_occupancy").unwrap().get("mean").is_some());
        let ht = v.get("host_transfer_bytes").unwrap();
        assert!(ht.get("to_device").is_some());
        assert!(ht.get("to_host").is_some());
        assert!(ht.get("admission_to_device").is_some());
        assert!(ht.get("admission_to_host").is_some());
        let tp = v.get("throughput").unwrap();
        assert!(tp.get("fused_admissions").is_some());
        assert!(tp.get("fused_splices").is_some());
        assert!(v.get("gather_cache").unwrap().get("hits").is_some());
        let spec = v.get("speculative").unwrap();
        assert!(spec.get("spec_ticks").is_some());
        assert!(spec.get("draft_tokens_proposed").is_some());
        assert!(spec.get("draft_tokens_accepted").is_some());
        assert!(spec.get("acceptance_pct").unwrap().get("p99_us").is_some());
        assert!(spec.get("verify_latency").is_some());
        let pc = v.get("prefix_cache").unwrap();
        for key in [
            "hits",
            "misses",
            "inserts",
            "evictions",
            "prefix_tokens_reused",
            "bytes_saved",
            "resident_bytes",
        ] {
            assert!(pc.get(key).is_some(), "prefix_cache.{key} missing");
        }
        assert!(v
            .get("throughput")
            .unwrap()
            .get("fused_decode_ticks")
            .is_some());
        // serializes without panicking
        let s = crate::json::to_string(&v);
        assert!(crate::json::parse(&s).is_ok());
    }

    #[test]
    fn absorb_merges_exactly() {
        let a = MetricsRegistry::default();
        let b = MetricsRegistry::default();
        for ms in [1u64, 2, 3] {
            a.ttft.record(Duration::from_millis(ms));
        }
        for ms in [10u64, 20] {
            b.ttft.record(Duration::from_millis(ms));
        }
        a.requests_completed.add(3);
        b.requests_completed.add(2);
        a.slots_busy.set(1);
        b.slots_busy.set(2);
        a.tokens_generated.add(30);
        b.tokens_generated.add(70);
        a.prefix_cache_hits.add(2);
        b.prefix_cache_hits.add(3);
        a.prefix_cache_bytes.set(100);
        b.prefix_cache_bytes.set(200);
        a.absorb(&b);
        assert_eq!(a.ttft.count(), 5);
        assert_eq!(a.ttft.max_us(), 20_000);
        assert!((a.ttft.mean_us() - 7200.0).abs() < 1.0);
        // percentiles see the union of samples, not an average of
        // summaries
        assert!(a.ttft.percentile_us(99.0) >= 20_000.0);
        assert_eq!(a.requests_completed.get(), 5);
        assert_eq!(a.slots_busy.get(), 3, "gauges sum across shards");
        assert_eq!(a.tokens_generated.total(), 100);
        assert_eq!(a.prefix_cache_hits.get(), 5);
        assert_eq!(a.prefix_cache_bytes.get(), 300,
                   "resident bytes sum like slot gauges");
        // b is read-only under absorb
        assert_eq!(b.ttft.count(), 2);
    }

    #[test]
    fn gauge_and_value_histogram() {
        let g = Gauge::default();
        assert_eq!(g.get(), 0);
        g.set(7);
        g.set(3);
        assert_eq!(g.get(), 3);
        let h = Histogram::new();
        for v in [2u64, 4, 4, 8] {
            h.record_value(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean_us() - 4.5).abs() < 1e-9);
        assert_eq!(h.max_us(), 8);
    }
}
