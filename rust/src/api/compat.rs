//! v1 compatibility shim: maps every legacy mode string onto the typed
//! v2 axes so existing clients, examples, and tooling keep working.
//!
//! Mapping table (mode string → prune axis):
//!
//! | v1 mode           | method    | strategy        |
//! |-------------------|-----------|-----------------|
//! | `full`            | none      | —               |
//! | `griffin`         | griffin   | topk            |
//! | `griffin-sampling`| griffin   | sampling        |
//! | `topk+sampling`   | griffin   | topk+sampling   |
//! | `magnitude`       | magnitude | —               |
//! | `wanda`           | wanda     | —               |
//!
//! The v1 `seed` field feeds BOTH axes (selection strategy and token
//! sampler) — v2 separates them as `prune.seed` / `sampling.seed`.
//! Sampler precedence is preserved exactly: temperature <= 0 is greedy
//! regardless of top_k/top_p, and top_k wins over top_p when both are
//! present (v2 proper rejects that combination; the shim keeps v1
//! clients working).
//!
//! One deliberate difference: v1 requests now pass the same
//! admission-time validation as v2 — `keep` outside (0,1], negative
//! temperature, and top_p outside (0,1] are rejected with
//! `invalid_request` instead of silently defaulting or failing later
//! inside the engine thread.

use crate::api::error::{ApiError, ErrorCode};
use crate::api::parse::{
    bool_field, f64_field, str_field, u64_field, usize_field,
};
use crate::api::types::{GenerateSpec, PruneSpec, Request, SamplingSpec};
use crate::json::Value;

/// Parse a v1 request line (no `"v"` field) into a typed [`Request`].
pub fn parse_v1(v: &Value) -> Result<Request, ApiError> {
    match str_field(v, "op")? {
        Some("generate") => Ok(Request::Generate(v1_generate_spec(v)?)),
        Some("metrics") => Ok(Request::Metrics),
        Some("config") => Ok(Request::Config),
        Some("shutdown") => Ok(Request::Shutdown),
        Some(other) => Err(ApiError::new(
            ErrorCode::UnknownOp,
            format!("unknown op {other:?}"),
        )),
        None => Err(ApiError::new(ErrorCode::UnknownOp, "missing op")),
    }
}

/// Lower a v1 generate body onto the typed v2 axes.
pub fn v1_generate_spec(v: &Value) -> Result<GenerateSpec, ApiError> {
    let prompt = str_field(v, "prompt")?
        .ok_or_else(|| ApiError::invalid("missing prompt"))?
        .to_string();
    let seed = u64_field(v, "seed")?.unwrap_or(0);
    let keep = f64_field(v, "keep")?.unwrap_or(0.5);
    let prune = PruneSpec::from_v1_mode(
        str_field(v, "mode")?.unwrap_or("full"), keep, seed)?;
    let spec = GenerateSpec {
        prompts: vec![prompt],
        max_new_tokens: usize_field(v, "max_new_tokens")?.unwrap_or(32),
        prune,
        sampling: SamplingSpec {
            temperature: f64_field(v, "temperature")?.unwrap_or(0.0)
                as f32,
            top_k: usize_field(v, "top_k")?,
            top_p: f64_field(v, "top_p")?,
            seed,
        },
        stop_at_eos: bool_field(v, "stop_at_eos")?.unwrap_or(true),
        stream: bool_field(v, "stream")?.unwrap_or(false),
        // session affinity is a v2 surface; v1 requests place least-loaded
        session: None,
        // speculative decoding is a v2 surface; v1 lines decode plainly
        speculative: None,
        v2: false,
    };
    spec.validate()?;
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::selection::Strategy;
    use crate::coordinator::types::Mode;
    use crate::json;
    use crate::sampling::SamplerSpec;

    fn spec(line: &str) -> GenerateSpec {
        v1_generate_spec(&json::parse(line).unwrap()).unwrap()
    }

    #[test]
    fn mode_string_mapping_table() {
        let cases: Vec<(&str, Mode)> = vec![
            (r#"{"prompt":"x","mode":"full"}"#, Mode::Full),
            (
                r#"{"prompt":"x","mode":"griffin","keep":0.5}"#,
                Mode::griffin(0.5),
            ),
            (
                r#"{"prompt":"x","mode":"griffin-sampling","keep":0.5,
                    "seed":7}"#,
                Mode::Griffin {
                    keep: 0.5,
                    strategy: Strategy::Sampling { seed: 7 },
                },
            ),
            (
                r#"{"prompt":"x","mode":"topk+sampling","keep":0.5,
                    "seed":9}"#,
                Mode::Griffin {
                    keep: 0.5,
                    strategy: Strategy::TopKPlusSampling { seed: 9 },
                },
            ),
            (
                r#"{"prompt":"x","mode":"magnitude","keep":0.25}"#,
                Mode::Magnitude { keep: 0.25 },
            ),
            (
                r#"{"prompt":"x","mode":"wanda","keep":0.5}"#,
                Mode::Wanda { keep: 0.5 },
            ),
        ];
        for (line, want) in cases {
            assert_eq!(spec(line).prune.to_mode(), want, "line {line}");
        }
    }

    #[test]
    fn v1_seed_feeds_both_axes() {
        let g = spec(
            r#"{"prompt":"x","mode":"griffin-sampling","seed":11,
                "temperature":0.9}"#,
        );
        assert_eq!(g.prune.seed, 11);
        assert_eq!(g.sampling.seed, 11);
    }

    #[test]
    fn v1_topk_wins_over_topp() {
        // v2 rejects the combination; the shim keeps the old precedence
        let g = spec(
            r#"{"prompt":"x","temperature":0.8,"top_k":5,"top_p":0.9}"#,
        );
        assert!(matches!(
            g.sampling.to_sampler(),
            SamplerSpec::TopK { k: 5, .. }
        ));
    }

    #[test]
    fn v1_now_validates_at_admission() {
        for line in [
            r#"{"op":"generate","prompt":"x","mode":"nope"}"#,
            r#"{"op":"generate","prompt":"x","mode":"griffin",
                "keep":-1.0}"#,
            r#"{"op":"generate","prompt":"x","temperature":-0.5}"#,
            r#"{"op":"generate","prompt":"x","temperature":0.8,
                "top_p":2.0}"#,
        ] {
            let e = v1_generate_spec(&json::parse(line).unwrap())
                .unwrap_err();
            assert_eq!(e.code, ErrorCode::InvalidRequest, "line {line}");
        }
    }
}
