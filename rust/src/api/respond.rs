//! Wire formatting for responses and stream events, both protocol
//! versions. v2 lines carry a `"v":2` envelope field; v1 lines are
//! byte-compatible with the pre-v2 server.

use crate::api::error::ApiError;
use crate::api::types::PROTOCOL_VERSION;
use crate::coordinator::types::GenResponse;
use crate::json::{self, n, obj, s, Value};

fn v2_wrap(mut v: Value) -> Value {
    if let Value::Obj(ref mut o) = v {
        o.insert(0, ("v".to_string(), n(PROTOCOL_VERSION as f64)));
    }
    v
}

/// One generation's response fields. `v2_schema` selects the v2 row
/// shape (adds the `prune` provenance object); it is independent of the
/// `"v"` envelope, which only [`response_json`] applies — batched v2
/// rows use the schema WITHOUT the per-row envelope.
fn response_body(r: &GenResponse, v2_schema: bool) -> Value {
    let mut fields = vec![
        ("op", s("generate")),
        ("id", n(r.id as f64)),
        ("text", s(&r.text)),
        (
            "tokens",
            Value::Arr(r.tokens.iter().map(|&t| n(t as f64)).collect()),
        ),
        ("finish", s(r.finish.as_str())),
        (
            "k_used",
            r.k_used.map(|k| n(k as f64)).unwrap_or(Value::Null),
        ),
    ];
    if v2_schema {
        if let Some(sel) = r.selection {
            let mut prune = vec![
                ("method", s(sel.method)),
                (
                    "strategy",
                    sel.strategy.map(s).unwrap_or(Value::Null),
                ),
                (
                    "seed",
                    sel.seed.map(|x| n(x as f64)).unwrap_or(Value::Null),
                ),
            ];
            // down-kept under overload: record the client's original
            // keep and flag the degradation (absent on responses served
            // as requested, so the non-degraded shape is unchanged)
            if let Some(kr) = sel.keep_requested {
                prune.push(("keep_requested", n(kr)));
                prune.push(("degraded", Value::Bool(true)));
            }
            // adaptive-layer provenance: the exact per-layer FF widths
            // the response decoded at (layer order). Absent on uniform
            // keeps, where `k_used` already tells the whole story.
            if let Some(ref lks) = r.k_per_layer {
                prune.push((
                    "k_per_layer",
                    Value::Arr(lks.iter().map(|&k| n(k as f64)).collect()),
                ));
            }
            fields.push(("prune", obj(prune)));
        }
        // speculative-decoding provenance: what the request opted into
        // and how the pruned drafter performed. accepted/proposed is the
        // serving-time readout of the paper's flocking claim; absent on
        // requests that never opted in, so the plain shape is unchanged.
        if let Some(sp) = r.speculative {
            fields.push((
                "speculative",
                obj(vec![
                    ("draft_tokens", n(sp.draft_tokens as f64)),
                    ("proposed", n(sp.proposed as f64)),
                    ("accepted", n(sp.accepted as f64)),
                ]),
            ));
        }
        // prefix-cache provenance: how much of the prompt was served
        // from device-resident KV (`hit`) vs prefilled. Present only on
        // responses admitted through the chunked/prefix path, so plain
        // single-shot responses keep their shape.
        if let Some(c) = r.cache {
            fields.push((
                "cache",
                obj(vec![
                    ("prefix_tokens", n(c.prefix_tokens as f64)),
                    ("hit", Value::Bool(c.hit)),
                ]),
            ));
        }
    }
    fields.push((
        "timing",
        obj(vec![
            ("prefill_ms", n(r.prefill_ms)),
            ("select_ms", n(r.select_ms)),
            ("decode_ms", n(r.decode_ms)),
            ("ttft_ms", n(r.ttft_ms)),
            ("tokens_per_sec", n(r.tokens_per_sec)),
        ]),
    ));
    obj(fields)
}

/// The response body of one completed generation. v2 responses carry
/// the `"v"` envelope and the `prune` provenance object (method /
/// strategy / strategy seed) so reproducibility audits can re-derive
/// the served expert selection; v1 bodies stay byte-compatible with
/// the pre-v2 server.
pub fn response_json(r: &GenResponse, v2: bool) -> Value {
    let body = response_body(r, v2);
    if v2 {
        v2_wrap(body)
    } else {
        body
    }
}

/// One embedded row of a batched v2 `results` array: the v2 row schema
/// (including `prune` provenance) WITHOUT the per-row `"v"` envelope —
/// only the outer batch line is versioned (uniform row schema). Batched
/// generate is a v2-only surface, so there is no v1 variant.
pub fn response_row_json(r: &GenResponse) -> Value {
    response_body(r, true)
}

/// Final line of a generate exchange (streaming adds the done event tag).
pub fn done_json(r: &GenResponse, stream: bool, v2: bool) -> String {
    let mut v = response_json(r, v2);
    if stream {
        if let Value::Obj(ref mut o) = v {
            let at = usize::from(v2); // after the "v" field
            o.insert(at, ("event".to_string(), s("done")));
        }
    }
    json::to_string(&v)
}

/// One streamed token event.
pub fn token_json(id: u64, index: usize, token: i32, text: &str, v2: bool)
                  -> String {
    let body = obj(vec![
        ("event", s("token")),
        ("id", n(id as f64)),
        ("index", n(index as f64)),
        ("token", n(token as f64)),
        ("text", s(text)),
    ]);
    json::to_string(&if v2 { v2_wrap(body) } else { body })
}

/// v2 streaming admission ack: tells the client its server-assigned
/// request id before the first token, so `cancel` can target it.
pub fn accepted_json(id: u64) -> String {
    json::to_string(&v2_wrap(obj(vec![
        ("event", s("accepted")),
        ("id", n(id as f64)),
    ])))
}

/// Batched-stream admission ack: one line, the server-assigned ids in
/// PROMPT ORDER — the id↔index mapping every later per-index event is
/// read against. Batched streaming is v2-only.
pub fn accepted_batch_json(ids: &[u64]) -> String {
    json::to_string(&v2_wrap(obj(vec![
        ("event", s("accepted")),
        (
            "ids",
            Value::Arr(ids.iter().map(|&id| n(id as f64)).collect()),
        ),
    ])))
}

/// One token event of a batched stream. `index` is the PROMPT index
/// (which lane of the batch this token belongs to); the token's
/// position within its sequence rides in `seq`. Single-prompt streams
/// keep the legacy [`token_json`] shape, where `index` is the token
/// position.
pub fn stream_token_json(index: usize, id: u64, seq: usize, token: i32,
                         text: &str) -> String {
    json::to_string(&v2_wrap(obj(vec![
        ("event", s("token")),
        ("index", n(index as f64)),
        ("id", n(id as f64)),
        ("seq", n(seq as f64)),
        ("token", n(token as f64)),
        ("text", s(text)),
    ])))
}

/// Per-index terminal event of a batched stream: the full v2 row schema
/// tagged `event:"done"` plus the prompt `index`. Lanes finish in
/// completion order; the stream ends after the last lane's terminal
/// event (there is no trailing batch line).
pub fn stream_done_json(r: &GenResponse, index: usize) -> String {
    let mut v = response_json(r, true);
    if let Value::Obj(ref mut o) = v {
        o.insert(1, ("event".to_string(), s("done")));
        o.insert(2, ("index".to_string(), n(index as f64)));
    }
    json::to_string(&v)
}

/// Per-index error event of a batched stream (admission rejection or
/// engine fault of one lane; the other lanes keep streaming).
pub fn stream_error_json(e: &ApiError, id: u64, index: usize) -> String {
    let mut v = error_obj(e, Some(id));
    if let Value::Obj(ref mut o) = v {
        o.insert(1, ("event".to_string(), s("error")));
        o.insert(2, ("index".to_string(), n(index as f64)));
    }
    json::to_string(&v2_wrap(v))
}

/// A structured error object; `id` ties it to an in-flight request.
/// (Batched generate embeds these in its `results` array.)
pub fn error_obj(e: &ApiError, id: Option<u64>) -> Value {
    let mut fields = vec![
        ("op", s("error")),
        ("code", s(e.code.as_str())),
        ("message", s(&e.message)),
    ];
    if let Some(ms) = e.retry_after_ms {
        fields.push(("retry_after_ms", n(ms as f64)));
    }
    if let Some(id) = id {
        fields.insert(1, ("id", n(id as f64)));
    }
    obj(fields)
}

/// A structured error line; `id` ties it to an in-flight request.
pub fn error_json(e: &ApiError, id: Option<u64>, v2: bool) -> String {
    let body = error_obj(e, id);
    json::to_string(&if v2 { v2_wrap(body) } else { body })
}

/// The batched-generate response: one line, per-prompt results in
/// request order (each entry a result object or an error object).
pub fn batch_json(results: Vec<Value>) -> String {
    json::to_string(&v2_wrap(obj(vec![
        ("op", s("generate")),
        ("results", Value::Arr(results)),
    ])))
}

/// The row body shared by the single-score line and batched rows:
/// per-token NLLs + perplexity of one continuation.
fn score_body(id: u64, nll: &[f64]) -> Value {
    let ppl = crate::eval::perplexity(nll.iter().sum(), nll.len());
    obj(vec![
        ("op", s("score")),
        ("id", n(id as f64)),
        ("nll", Value::Arr(nll.iter().map(|&x| n(x)).collect())),
        ("ppl", n(ppl)),
        ("tokens", n(nll.len() as f64)),
    ])
}

/// Score response: per-token NLLs + perplexity of the continuation.
pub fn score_json(id: u64, nll: &[f64]) -> String {
    json::to_string(&v2_wrap(score_body(id, nll)))
}

/// One embedded row of a batched score `results` array (no per-row
/// `"v"` envelope — only the outer batch line is versioned, matching
/// batched generate).
pub fn score_row_json(id: u64, nll: &[f64]) -> Value {
    score_body(id, nll)
}

/// The batched-score response: one line, per-row results in REQUEST
/// ORDER (each entry a score row or an error object), mirroring
/// [`batch_json`]. Batched score is a v2-only surface.
pub fn score_batch_json(results: Vec<Value>) -> String {
    json::to_string(&v2_wrap(obj(vec![
        ("op", s("score")),
        ("results", Value::Arr(results)),
    ])))
}

/// Cancel acknowledgment (`status`: "cancelling" | "unknown_id").
pub fn cancel_ack_json(id: u64, status: &str) -> String {
    json::to_string(&v2_wrap(obj(vec![
        ("op", s("cancel")),
        ("id", n(id as f64)),
        ("status", s(status)),
    ])))
}

/// Liveness + capacity snapshot, answerable off the engine thread.
/// Fleet-level `slots`/`queue` are sums over the engine shards; each
/// `shards` entry breaks the same numbers out per shard (built by the
/// server, which owns the shard state). `status` is `"ok"` while every
/// shard is healthy, `"degraded"` once any shard is poisoned —
/// `queue_depth` counts generate admissions, `score_depth` the score
/// queue — both share `queue_capacity` as their per-queue cap.
pub fn health_json(status: &str, slots_busy: u64, slots_total: u64,
                   queue_depth: usize, score_depth: usize,
                   queue_capacity: usize, shards: Vec<Value>) -> String {
    json::to_string(&v2_wrap(obj(vec![
        ("op", s("health")),
        ("status", s(status)),
        (
            "slots",
            obj(vec![
                ("busy", n(slots_busy as f64)),
                ("total", n(slots_total as f64)),
            ]),
        ),
        (
            "queue",
            obj(vec![
                ("depth", n(queue_depth as f64)),
                ("score_depth", n(score_depth as f64)),
                ("capacity", n(queue_capacity as f64)),
            ]),
        ),
        ("shards", Value::Arr(shards)),
    ])))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sequence::FinishReason;

    fn resp() -> GenResponse {
        GenResponse {
            id: 3,
            tokens: vec![104],
            text: "h".into(),
            logprobs: vec![-0.1],
            finish: FinishReason::Length,
            k_used: None,
            k_per_layer: None,
            selection: None,
            speculative: None,
            cache: None,
            prefill_ms: 1.0,
            select_ms: 0.0,
            decode_ms: 2.0,
            ttft_ms: 1.5,
            tokens_per_sec: 500.0,
        }
    }

    #[test]
    fn v1_lines_carry_no_version_field() {
        let d = json::parse(&done_json(&resp(), false, false)).unwrap();
        assert!(d.get("v").is_none());
        assert_eq!(d.get("op").unwrap().as_str(), Some("generate"));
        let t = json::parse(&token_json(3, 1, 104, "h", false)).unwrap();
        assert!(t.get("v").is_none());
        assert_eq!(t.get("event").unwrap().as_str(), Some("token"));
    }

    #[test]
    fn v2_lines_are_versioned() {
        let d = json::parse(&done_json(&resp(), true, true)).unwrap();
        assert_eq!(d.get("v").unwrap().as_usize(), Some(2));
        assert_eq!(d.get("event").unwrap().as_str(), Some("done"));
        let a = json::parse(&accepted_json(9)).unwrap();
        assert_eq!(a.get("event").unwrap().as_str(), Some("accepted"));
        assert_eq!(a.get("id").unwrap().as_usize(), Some(9));
    }

    #[test]
    fn error_lines_carry_code_and_id() {
        let e = ApiError::invalid("bad keep");
        let v = json::parse(&error_json(&e, Some(7), true)).unwrap();
        assert_eq!(v.get("op").unwrap().as_str(), Some("error"));
        assert_eq!(v.get("code").unwrap().as_str(), Some("invalid_request"));
        assert_eq!(v.get("id").unwrap().as_usize(), Some(7));
        let v = json::parse(&error_json(&e, None, false)).unwrap();
        assert!(v.get("id").is_none());
        assert!(v.get("v").is_none());
    }

    #[test]
    fn v2_surfaces_selection_provenance() {
        use crate::coordinator::types::SelectionInfo;
        let mut r = resp();
        r.k_used = Some(128);
        r.selection = Some(SelectionInfo {
            method: "griffin",
            strategy: Some("sampling"),
            seed: Some(7),
            keep_requested: None,
        });
        let d = json::parse(&done_json(&r, false, true)).unwrap();
        let p = d.get("prune").expect("v2 carries prune provenance");
        assert_eq!(p.get("method").unwrap().as_str(), Some("griffin"));
        assert_eq!(p.get("strategy").unwrap().as_str(), Some("sampling"));
        assert_eq!(p.get("seed").unwrap().as_usize(), Some(7));
        // deterministic top-k: strategy present, seed null
        r.selection = Some(SelectionInfo {
            method: "griffin",
            strategy: Some("topk"),
            seed: None,
            keep_requested: None,
        });
        let d = json::parse(&done_json(&r, false, true)).unwrap();
        assert!(matches!(d.get("prune").unwrap().get("seed"),
                         Some(Value::Null)));
        // v1 bodies stay byte-compatible: no prune object ever
        let d1 = json::parse(&done_json(&r, false, false)).unwrap();
        assert!(d1.get("prune").is_none());
        // full model: nothing to audit, no prune object
        r.selection = None;
        let d = json::parse(&done_json(&r, false, true)).unwrap();
        assert!(d.get("prune").is_none());
    }

    #[test]
    fn v2_surfaces_per_layer_keep_provenance() {
        use crate::coordinator::types::SelectionInfo;
        let mut r = resp();
        r.k_used = Some(16);
        r.k_per_layer = Some(vec![8, 24]);
        r.selection = Some(SelectionInfo {
            method: "griffin",
            strategy: Some("adaptive-layer"),
            seed: None,
            keep_requested: None,
        });
        let d = json::parse(&done_json(&r, false, true)).unwrap();
        let p = d.get("prune").unwrap();
        assert_eq!(p.get("strategy").unwrap().as_str(),
                   Some("adaptive-layer"));
        let Some(Value::Arr(lks)) = p.get("k_per_layer") else {
            panic!("adaptive responses disclose per-layer widths");
        };
        assert_eq!(lks.len(), 2);
        assert_eq!(lks[0].as_usize(), Some(8));
        assert_eq!(lks[1].as_usize(), Some(24));
        // embedded batch rows keep the array (same row schema)
        let row = response_row_json(&r);
        assert!(row.get("prune").unwrap().get("k_per_layer").is_some());
        // v1 bodies stay byte-compatible: no prune object at all
        let d1 = json::parse(&done_json(&r, false, false)).unwrap();
        assert!(d1.get("prune").is_none());
        // uniform keeps: no per-layer array (shape unchanged)
        r.k_per_layer = None;
        r.selection = Some(SelectionInfo {
            method: "griffin",
            strategy: Some("topk"),
            seed: None,
            keep_requested: None,
        });
        let d = json::parse(&done_json(&r, false, true)).unwrap();
        assert!(d.get("prune").unwrap().get("k_per_layer").is_none());
    }

    #[test]
    fn degraded_responses_surface_requested_keep() {
        use crate::coordinator::types::SelectionInfo;
        let mut r = resp();
        r.k_used = Some(64);
        // down-kept under overload: the prune object records what the
        // client asked for and flags the degradation
        r.selection = Some(SelectionInfo {
            method: "griffin",
            strategy: Some("topk"),
            seed: None,
            keep_requested: Some(0.75),
        });
        let d = json::parse(&done_json(&r, false, true)).unwrap();
        let p = d.get("prune").unwrap();
        let kr = p.get("keep_requested").unwrap().as_f64().unwrap();
        assert!((kr - 0.75).abs() < 1e-12);
        assert_eq!(p.get("degraded").unwrap().as_bool(), Some(true));
        // served as requested: neither field appears (shape unchanged)
        r.selection = Some(SelectionInfo {
            method: "griffin",
            strategy: Some("topk"),
            seed: None,
            keep_requested: None,
        });
        let d = json::parse(&done_json(&r, false, true)).unwrap();
        let p = d.get("prune").unwrap();
        assert!(p.get("keep_requested").is_none());
        assert!(p.get("degraded").is_none());
    }

    #[test]
    fn overloaded_errors_carry_retry_after() {
        let mut e = ApiError::new(crate::api::ErrorCode::Overloaded,
                                  "fleet overloaded");
        e.retry_after_ms = Some(120);
        let v = json::parse(&error_json(&e, Some(4), true)).unwrap();
        assert_eq!(v.get("code").unwrap().as_str(), Some("overloaded"));
        assert_eq!(v.get("retry_after_ms").unwrap().as_usize(), Some(120));
        let row = error_obj(&e, None);
        assert_eq!(row.get("retry_after_ms").unwrap().as_usize(),
                   Some(120));
        // non-retryable errors keep the old shape
        let plain = ApiError::invalid("bad keep");
        let v = json::parse(&error_json(&plain, None, true)).unwrap();
        assert!(v.get("retry_after_ms").is_none());
    }

    #[test]
    fn batched_rows_keep_provenance_without_envelope() {
        use crate::coordinator::types::SelectionInfo;
        let mut r = resp();
        r.selection = Some(SelectionInfo {
            method: "griffin",
            strategy: Some("topk"),
            seed: None,
            keep_requested: None,
        });
        let row = response_row_json(&r);
        assert!(row.get("v").is_none(),
                "embedded rows carry no per-row envelope");
        assert_eq!(
            row.get("prune").unwrap().get("method").unwrap().as_str(),
            Some("griffin"),
            "batched rows must not lose the provenance object"
        );
    }

    #[test]
    fn v2_surfaces_speculative_provenance() {
        use crate::coordinator::types::SpecInfo;
        let mut r = resp();
        r.speculative = Some(SpecInfo {
            draft_tokens: 4,
            proposed: 30,
            accepted: 21,
        });
        let d = json::parse(&done_json(&r, false, true)).unwrap();
        let sp = d.get("speculative").expect("v2 carries spec provenance");
        assert_eq!(sp.get("draft_tokens").unwrap().as_usize(), Some(4));
        assert_eq!(sp.get("proposed").unwrap().as_usize(), Some(30));
        assert_eq!(sp.get("accepted").unwrap().as_usize(), Some(21));
        // v1 bodies stay byte-compatible: never a speculative object
        let d1 = json::parse(&done_json(&r, false, false)).unwrap();
        assert!(d1.get("speculative").is_none());
        // no opt-in, no object (plain response shape unchanged)
        r.speculative = None;
        let d = json::parse(&done_json(&r, false, true)).unwrap();
        assert!(d.get("speculative").is_none());
    }

    #[test]
    fn v2_surfaces_prefix_cache_provenance() {
        use crate::coordinator::types::CacheInfo;
        let mut r = resp();
        r.cache = Some(CacheInfo { prefix_tokens: 32, hit: true });
        let d = json::parse(&done_json(&r, false, true)).unwrap();
        let c = d.get("cache").expect("v2 carries cache provenance");
        assert_eq!(c.get("prefix_tokens").unwrap().as_usize(), Some(32));
        assert_eq!(c.get("hit").unwrap().as_bool(), Some(true));
        // a cold chunked admission reports the miss explicitly
        r.cache = Some(CacheInfo { prefix_tokens: 0, hit: false });
        let d = json::parse(&done_json(&r, false, true)).unwrap();
        let c = d.get("cache").unwrap();
        assert_eq!(c.get("prefix_tokens").unwrap().as_usize(), Some(0));
        assert_eq!(c.get("hit").unwrap().as_bool(), Some(false));
        // embedded batch rows keep the object (same row schema)
        let row = response_row_json(&r);
        assert!(row.get("cache").is_some());
        // v1 bodies stay byte-compatible: never a cache object
        let d1 = json::parse(&done_json(&r, false, false)).unwrap();
        assert!(d1.get("cache").is_none());
        // single-shot admissions: no object (plain shape unchanged)
        r.cache = None;
        let d = json::parse(&done_json(&r, false, true)).unwrap();
        assert!(d.get("cache").is_none());
    }

    #[test]
    fn batched_score_rows_assemble_without_envelope() {
        let row = score_row_json(4, &[1.0, 1.0]);
        assert!(row.get("v").is_none(),
                "embedded rows carry no per-row envelope");
        assert_eq!(row.get("op").unwrap().as_str(), Some("score"));
        let e = ApiError::invalid("row 1 rejected");
        let line = score_batch_json(vec![row, error_obj(&e, None)]);
        let v = json::parse(&line).unwrap();
        assert_eq!(v.get("v").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("op").unwrap().as_str(), Some("score"));
        let Some(Value::Arr(rows)) = v.get("results") else {
            panic!("batched score carries a results array");
        };
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("id").unwrap().as_usize(), Some(4));
        let ppl = rows[0].get("ppl").unwrap().as_f64().unwrap();
        assert!((ppl - std::f64::consts::E).abs() < 1e-9);
        assert_eq!(rows[1].get("op").unwrap().as_str(), Some("error"));
    }

    #[test]
    fn cancelled_finish_serializes() {
        let mut r = resp();
        r.finish = FinishReason::Cancelled;
        let d = json::parse(&done_json(&r, false, true)).unwrap();
        assert_eq!(d.get("finish").unwrap().as_str(), Some("cancelled"));
    }

    #[test]
    fn score_json_reports_ppl() {
        let v = json::parse(&score_json(4, &[1.0, 1.0])).unwrap();
        assert_eq!(v.get("op").unwrap().as_str(), Some("score"));
        assert_eq!(v.get("tokens").unwrap().as_usize(), Some(2));
        let ppl = v.get("ppl").unwrap().as_f64().unwrap();
        assert!((ppl - std::f64::consts::E).abs() < 1e-9);
    }

    #[test]
    fn health_json_shape() {
        let shard = obj(vec![("shard", n(0.0)), ("status", s("ok"))]);
        let v =
            json::parse(&health_json("ok", 2, 4, 1, 3, 64, vec![shard]))
                .unwrap();
        assert_eq!(v.get("status").unwrap().as_str(), Some("ok"));
        assert_eq!(
            v.get("slots").unwrap().get("total").unwrap().as_usize(),
            Some(4)
        );
        let q = v.get("queue").unwrap();
        assert_eq!(q.get("depth").unwrap().as_usize(), Some(1));
        assert_eq!(q.get("score_depth").unwrap().as_usize(), Some(3));
        assert_eq!(q.get("capacity").unwrap().as_usize(), Some(64));
        let Some(Value::Arr(shards)) = v.get("shards") else {
            panic!("health carries a per-shard breakdown");
        };
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].get("shard").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn batched_stream_events_carry_prompt_index() {
        // accepted: ids in prompt order — the id↔index contract
        let a = json::parse(&accepted_batch_json(&[7, 8])).unwrap();
        assert_eq!(a.get("event").unwrap().as_str(), Some("accepted"));
        let Some(Value::Arr(ids)) = a.get("ids") else {
            panic!("batched accepted carries the id list");
        };
        assert_eq!(ids.len(), 2);
        assert_eq!(ids[1].as_usize(), Some(8));
        // token: index = prompt lane, seq = token position
        let t =
            json::parse(&stream_token_json(1, 8, 3, 104, "h")).unwrap();
        assert_eq!(t.get("v").unwrap().as_usize(), Some(2));
        assert_eq!(t.get("index").unwrap().as_usize(), Some(1));
        assert_eq!(t.get("id").unwrap().as_usize(), Some(8));
        assert_eq!(t.get("seq").unwrap().as_usize(), Some(3));
        // done: full v2 row + event tag + lane index
        let d = json::parse(&stream_done_json(&resp(), 1)).unwrap();
        assert_eq!(d.get("event").unwrap().as_str(), Some("done"));
        assert_eq!(d.get("index").unwrap().as_usize(), Some(1));
        assert_eq!(d.get("finish").unwrap().as_str(), Some("length"));
        // error: lane-scoped failure keeps the stream alive
        let e = ApiError::new(crate::api::ErrorCode::QueueFull, "full");
        let v = json::parse(&stream_error_json(&e, 9, 0)).unwrap();
        assert_eq!(v.get("event").unwrap().as_str(), Some("error"));
        assert_eq!(v.get("index").unwrap().as_usize(), Some(0));
        assert_eq!(v.get("code").unwrap().as_str(), Some("queue_full"));
    }
}
