//! Versioned, typed serving API (wire protocol v2 + the v1 shim).
//!
//! This module owns the public request/response surface of the server:
//!
//! - [`types`]   — the typed request model: orthogonal `prune`
//!   ({method, keep, strategy, seed}) and `sampling` ({temperature,
//!   top_k, top_p, seed}) axes, plus the op set (`generate` with one or
//!   many prompts, `score`, `cancel`, `health`, `metrics`, `config`,
//!   `shutdown`).
//! - [`parse`]   — v2 parsing with admission-time validation: malformed
//!   requests are rejected with structured `invalid_request` errors
//!   before they reach the engine thread.
//! - [`compat`]  — the v1 shim: every legacy mode string
//!   (`full | griffin | griffin-sampling | topk+sampling | magnitude |
//!   wanda`) maps onto the same typed axes, so v1 clients keep working.
//! - [`error`]   — stable machine-readable [`ErrorCode`]s.
//! - [`respond`] — response/event line formatting for both versions.
//!
//! Everything here is runtime-free (no PJRT): it builds and unit-tests
//! with `--no-default-features`, and `server/` (runtime-gated) is a thin
//! IO layer over it. See docs/protocol.md for the wire format.

pub mod compat;
pub mod error;
pub mod parse;
pub mod respond;
pub mod types;

pub use error::{ApiError, ErrorCode};
pub use parse::{parse_request, request_version};
pub use respond::{
    accepted_batch_json, accepted_json, batch_json, cancel_ack_json,
    done_json, error_json, error_obj, health_json, response_json,
    response_row_json, score_batch_json, score_json, score_row_json,
    stream_done_json, stream_error_json, stream_token_json, token_json,
};
pub use types::{
    GenerateSpec, PruneMethod, PruneSpec, Request, SamplingSpec, ScoreSpec,
    SelectionStrategy, PROTOCOL_VERSION,
};
