//! Typed v2 request model: orthogonal `prune` and `sampling` axes.
//!
//! The v1 wire protocol conflated pruning method, expert-selection
//! strategy, and token sampler into single mode strings
//! (`"griffin-sampling"`, `"topk+sampling"`). v2 splits them into
//! independent objects so new pruning/selection scenarios land as data,
//! not as new string variants parsed in four places:
//!
//!   prune:    {method, keep, strategy, seed}   — what runs per step
//!   sampling: {temperature, top_k, top_p, seed} — how tokens are drawn
//!
//! Validation happens here, at admission time, so malformed requests are
//! rejected with a structured `invalid_request` error before they ever
//! reach the engine thread.

use std::time::Instant;

use crate::api::error::ApiError;
use crate::coordinator::selection::Strategy;
use crate::coordinator::sequence::{GenRequest, ScoreRequest};
use crate::coordinator::types::Mode;
use crate::sampling::SamplerSpec;
use crate::tokenizer::Tokenizer;

/// Highest protocol version this build speaks.
pub const PROTOCOL_VERSION: u64 = 2;

/// The pruning method applied during the generation phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PruneMethod {
    /// full model, no pruning
    None,
    /// GRIFFIN: prompt-prompted expert selection (the paper's method)
    Griffin,
    /// static magnitude pruning (structured baseline)
    Magnitude,
    /// adaptive Wanda masking (unstructured baseline)
    Wanda,
}

impl PruneMethod {
    pub fn as_str(&self) -> &'static str {
        match self {
            PruneMethod::None => "none",
            PruneMethod::Griffin => "griffin",
            PruneMethod::Magnitude => "magnitude",
            PruneMethod::Wanda => "wanda",
        }
    }
}

/// Expert-selection strategy (GRIFFIN only; ignored by other methods).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectionStrategy {
    TopK,
    Sampling,
    TopKPlusSampling,
    /// non-uniform per-layer keep under a global FLOP budget
    /// (v2-only axis; no v1 mode string maps to it)
    AdaptiveLayer,
}

impl SelectionStrategy {
    pub fn as_str(&self) -> &'static str {
        match self {
            SelectionStrategy::TopK => "topk",
            SelectionStrategy::Sampling => "sampling",
            SelectionStrategy::TopKPlusSampling => "topk+sampling",
            SelectionStrategy::AdaptiveLayer => "adaptive-layer",
        }
    }
}

/// The orthogonal pruning axis of a v2 request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PruneSpec {
    pub method: PruneMethod,
    /// FF keep fraction in (0,1]; ignored when method == None
    pub keep: f64,
    pub strategy: SelectionStrategy,
    /// seed for stochastic selection strategies
    pub seed: u64,
}

impl Default for PruneSpec {
    fn default() -> Self {
        PruneSpec {
            method: PruneMethod::None,
            keep: 0.5,
            strategy: SelectionStrategy::TopK,
            seed: 0,
        }
    }
}

impl PruneSpec {
    /// THE v1 mode-string mapping table (`full | griffin |
    /// griffin-sampling | topk+sampling | magnitude | wanda`), shared by
    /// the wire compat shim and the CLI so the two surfaces cannot
    /// drift. Unknown strings are `invalid_request`; the result is NOT
    /// yet validated (callers validate the whole spec).
    pub fn from_v1_mode(mode: &str, keep: f64, seed: u64)
                        -> Result<PruneSpec, ApiError> {
        let (method, strategy) = match mode {
            "full" => (PruneMethod::None, SelectionStrategy::TopK),
            "griffin" => (PruneMethod::Griffin, SelectionStrategy::TopK),
            "griffin-sampling" => {
                (PruneMethod::Griffin, SelectionStrategy::Sampling)
            }
            "topk+sampling" => (
                PruneMethod::Griffin,
                SelectionStrategy::TopKPlusSampling,
            ),
            "magnitude" => {
                (PruneMethod::Magnitude, SelectionStrategy::TopK)
            }
            "wanda" => (PruneMethod::Wanda, SelectionStrategy::TopK),
            other => {
                return Err(ApiError::invalid(format!(
                    "unknown mode {other:?}"
                )))
            }
        };
        Ok(PruneSpec { method, keep, strategy, seed })
    }

    /// Admission-time validation: keep must lie in (0,1] for every
    /// pruning method (NaN fails too).
    pub fn validate(&self) -> Result<(), ApiError> {
        if self.method != PruneMethod::None
            && (self.keep.is_nan() || self.keep <= 0.0 || self.keep > 1.0)
        {
            return Err(ApiError::invalid(format!(
                "prune.keep must be in (0,1], got {}",
                self.keep
            )));
        }
        Ok(())
    }

    /// Lower to the engine's `Mode` (validated specs only).
    pub fn to_mode(&self) -> Mode {
        match self.method {
            PruneMethod::None => Mode::Full,
            PruneMethod::Griffin => Mode::Griffin {
                keep: self.keep,
                strategy: match self.strategy {
                    SelectionStrategy::TopK => Strategy::TopK,
                    SelectionStrategy::Sampling => {
                        Strategy::Sampling { seed: self.seed }
                    }
                    SelectionStrategy::TopKPlusSampling => {
                        Strategy::TopKPlusSampling { seed: self.seed }
                    }
                    SelectionStrategy::AdaptiveLayer => {
                        Strategy::AdaptiveLayer
                    }
                },
            },
            PruneMethod::Magnitude => Mode::Magnitude { keep: self.keep },
            PruneMethod::Wanda => Mode::Wanda { keep: self.keep },
        }
    }
}

/// The orthogonal sampling axis of a v2 request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplingSpec {
    /// 0 (or below) = greedy decoding
    pub temperature: f32,
    pub top_k: Option<usize>,
    pub top_p: Option<f64>,
    pub seed: u64,
}

impl Default for SamplingSpec {
    fn default() -> Self {
        SamplingSpec { temperature: 0.0, top_k: None, top_p: None, seed: 0 }
    }
}

impl SamplingSpec {
    /// Admission-time validation. Negative (or NaN) temperature,
    /// top_k == 0 and top_p outside (0,1] are rejected instead of
    /// silently defaulting.
    pub fn validate(&self) -> Result<(), ApiError> {
        if self.temperature.is_nan() || self.temperature < 0.0 {
            return Err(ApiError::invalid(format!(
                "sampling.temperature must be >= 0, got {}",
                self.temperature
            )));
        }
        if self.top_k == Some(0) {
            return Err(ApiError::invalid("sampling.top_k must be >= 1"));
        }
        if let Some(p) = self.top_p {
            if p.is_nan() || p <= 0.0 || p > 1.0 {
                return Err(ApiError::invalid(format!(
                    "sampling.top_p must be in (0,1], got {p}"
                )));
            }
        }
        Ok(())
    }

    /// Lower to the engine's `SamplerSpec`. Precedence matches the v1
    /// parser exactly (compat shim round-trips depend on it):
    /// temperature <= 0 is greedy regardless of top_k/top_p; otherwise
    /// top_k wins over top_p.
    pub fn to_sampler(&self) -> SamplerSpec {
        if self.temperature <= 0.0 {
            SamplerSpec::Greedy
        } else if let Some(k) = self.top_k {
            SamplerSpec::TopK { k, temperature: self.temperature }
        } else if let Some(p) = self.top_p {
            SamplerSpec::TopP { p: p as f32, temperature: self.temperature }
        } else {
            SamplerSpec::Temperature(self.temperature)
        }
    }
}

/// A validated generate request (one or many prompts).
#[derive(Debug, Clone)]
pub struct GenerateSpec {
    pub prompts: Vec<String>,
    pub max_new_tokens: usize,
    pub prune: PruneSpec,
    pub sampling: SamplingSpec,
    pub stop_at_eos: bool,
    pub stream: bool,
    /// client-supplied shard-affinity key (v2): requests sharing it are
    /// placed on the same engine shard and never moved by work stealing
    pub session: Option<String>,
    /// self-speculative decoding opt-in (v2 `speculative:{draft_tokens}`
    /// axis): requested draft length per spec tick. The scheduler snaps
    /// it to a compiled verify bucket and falls back to plain decode on
    /// spec-ineligible ticks; the stream is byte-identical either way.
    pub speculative: Option<usize>,
    /// arrived under the v2 envelope (controls response formatting)
    pub v2: bool,
}

/// Largest draft length the `speculative` axis accepts at admission.
/// Liberal on purpose: the served length snaps DOWN to a compiled
/// verify bucket per tick, so any sane value works — this bound only
/// rejects nonsense that could never fit a decode window.
pub const MAX_DRAFT_TOKENS: usize = 64;

impl GenerateSpec {
    pub fn validate(&self) -> Result<(), ApiError> {
        if self.prompts.is_empty() {
            return Err(ApiError::invalid("no prompts"));
        }
        if self.max_new_tokens == 0 {
            return Err(ApiError::invalid("max_new_tokens must be >= 1"));
        }
        if let Some(d) = self.speculative {
            if d == 0 || d > MAX_DRAFT_TOKENS {
                return Err(ApiError::invalid(format!(
                    "speculative.draft_tokens must be in \
                     [1,{MAX_DRAFT_TOKENS}], got {d}"
                )));
            }
        }
        self.prune.validate()?;
        self.sampling.validate()
    }

    /// Lower to engine requests, one per prompt (ids are assigned by the
    /// router at admission).
    pub fn to_requests(&self, tok: &Tokenizer) -> Vec<GenRequest> {
        self.prompts
            .iter()
            .map(|p| GenRequest {
                id: 0,
                prompt: tok.encode_with_bos(p),
                max_new_tokens: self.max_new_tokens,
                mode: self.prune.to_mode(),
                sampler: self.sampling.to_sampler(),
                seed: self.sampling.seed,
                stop_at_eos: self.stop_at_eos,
                session: self.session.clone(),
                keep_requested: None,
                speculative: self.speculative,
                admitted_at: Instant::now(),
            })
            .collect()
    }
}

/// A validated score request (teacher-forced logprob evaluation), one
/// or many rows. The batched form (`prompts` + `continuations`, paired
/// by index) mirrors batched generate: rows are lowered to independent
/// engine requests and the response assembles per-row results back in
/// REQUEST ORDER, whatever order the engine finished them in.
#[derive(Debug, Clone)]
pub struct ScoreSpec {
    pub prompts: Vec<String>,
    pub continuations: Vec<String>,
    pub prune: PruneSpec,
    /// arrived via the singular `prompt`/`continuation` fields (controls
    /// response shape: one score line, not a batched `results` array)
    pub single: bool,
}

impl ScoreSpec {
    pub fn validate(&self) -> Result<(), ApiError> {
        if self.prompts.is_empty() {
            return Err(ApiError::invalid("score needs at least one row"));
        }
        if self.prompts.len() != self.continuations.len() {
            return Err(ApiError::invalid(format!(
                "score rows must pair up: {} prompts vs {} continuations",
                self.prompts.len(),
                self.continuations.len()
            )));
        }
        if self.prompts.iter().any(String::is_empty) {
            return Err(ApiError::invalid("score.prompt must be non-empty"));
        }
        if self.continuations.iter().any(String::is_empty) {
            return Err(ApiError::invalid(
                "score.continuation must be non-empty",
            ));
        }
        self.prune.validate()
    }

    /// Lower to engine requests, one per row (ids are assigned by the
    /// router at admission).
    pub fn to_requests(&self, tok: &Tokenizer) -> Vec<ScoreRequest> {
        self.prompts
            .iter()
            .zip(&self.continuations)
            .map(|(p, c)| ScoreRequest {
                id: 0,
                prompt: tok.encode_with_bos(p),
                continuation: tok.encode(c),
                mode: self.prune.to_mode(),
                admitted_at: Instant::now(),
            })
            .collect()
    }
}

/// A parsed protocol request, any version (the v1 shim lowers v1 lines
/// into the same typed requests).
#[derive(Debug, Clone)]
pub enum Request {
    Generate(GenerateSpec),
    Score(ScoreSpec),
    Cancel { id: u64 },
    Health,
    Metrics,
    Config,
    Shutdown,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prune_validation_bounds_keep() {
        let mut p = PruneSpec { method: PruneMethod::Griffin, ..Default::default() };
        p.keep = 0.5;
        assert!(p.validate().is_ok());
        p.keep = 1.0;
        assert!(p.validate().is_ok());
        for bad in [0.0, -1.0, 1.5, f64::NAN] {
            p.keep = bad;
            assert!(p.validate().is_err(), "keep={bad} must be rejected");
        }
        // full model ignores keep entirely
        p.method = PruneMethod::None;
        p.keep = -3.0;
        assert!(p.validate().is_ok());
    }

    #[test]
    fn sampling_validation() {
        let mut s = SamplingSpec::default();
        assert!(s.validate().is_ok());
        s.temperature = -0.1;
        assert!(s.validate().is_err());
        s.temperature = f32::NAN;
        assert!(s.validate().is_err());
        s.temperature = 0.8;
        s.top_k = Some(0);
        assert!(s.validate().is_err());
        s.top_k = Some(4);
        assert!(s.validate().is_ok());
        s.top_k = None;
        for bad in [0.0, -0.5, 1.2] {
            s.top_p = Some(bad);
            assert!(s.validate().is_err(), "top_p={bad} must be rejected");
        }
        s.top_p = Some(0.9);
        assert!(s.validate().is_ok());
    }

    #[test]
    fn sampler_precedence_matches_v1() {
        // temperature <= 0 is greedy even with top_k set (v1 behavior)
        let s = SamplingSpec { temperature: 0.0, top_k: Some(5), ..Default::default() };
        assert_eq!(s.to_sampler(), SamplerSpec::Greedy);
        let s = SamplingSpec { temperature: 0.8, top_k: Some(5), top_p: Some(0.9), seed: 0 };
        assert!(matches!(s.to_sampler(), SamplerSpec::TopK { k: 5, .. }));
        let s = SamplingSpec { temperature: 0.8, top_k: None, top_p: Some(0.9), seed: 0 };
        assert!(matches!(s.to_sampler(), SamplerSpec::TopP { .. }));
        let s = SamplingSpec { temperature: 0.8, ..Default::default() };
        assert!(matches!(s.to_sampler(), SamplerSpec::Temperature(_)));
    }

    #[test]
    fn prune_lowers_to_modes() {
        let p = PruneSpec {
            method: PruneMethod::Griffin,
            keep: 0.5,
            strategy: SelectionStrategy::TopKPlusSampling,
            seed: 9,
        };
        assert_eq!(
            p.to_mode(),
            Mode::Griffin {
                keep: 0.5,
                strategy: Strategy::TopKPlusSampling { seed: 9 },
            }
        );
        assert_eq!(PruneSpec::default().to_mode(), Mode::Full);
        // adaptive-layer lowers to the seedless engine strategy
        let a = PruneSpec {
            method: PruneMethod::Griffin,
            keep: 0.5,
            strategy: SelectionStrategy::AdaptiveLayer,
            seed: 7,
        };
        assert_eq!(
            a.to_mode(),
            Mode::Griffin { keep: 0.5, strategy: Strategy::AdaptiveLayer }
        );
        assert_eq!(SelectionStrategy::AdaptiveLayer.as_str(),
                   "adaptive-layer");
    }

    #[test]
    fn no_v1_mode_maps_to_adaptive_layer() {
        // adaptive-layer is a v2-only axis: the v1 table must not grow
        // a string for it (the compat surface is frozen)
        for mode in ["adaptive-layer", "griffin-adaptive",
                     "adaptive_layer"] {
            assert!(PruneSpec::from_v1_mode(mode, 0.5, 0).is_err(),
                    "v1 mode {mode:?} must be rejected");
        }
    }

    #[test]
    fn generate_spec_accepts_batched_streaming() {
        // batched streaming is a supported v2 surface: per-index token
        // events with per-index done lines (docs/protocol.md)
        let spec = GenerateSpec {
            prompts: vec!["a".into(), "b".into()],
            max_new_tokens: 4,
            prune: PruneSpec::default(),
            sampling: SamplingSpec::default(),
            stop_at_eos: true,
            stream: true,
            session: None,
            speculative: None,
            v2: true,
        };
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn speculative_axis_validates_draft_length() {
        let mut spec = GenerateSpec {
            prompts: vec!["a".into()],
            max_new_tokens: 4,
            prune: PruneSpec::default(),
            sampling: SamplingSpec::default(),
            stop_at_eos: true,
            stream: false,
            session: None,
            speculative: Some(4),
            v2: true,
        };
        assert!(spec.validate().is_ok());
        // draft length below the smallest compiled bucket is still a
        // valid opt-in: the scheduler just never finds a bucket and the
        // request decodes plainly (byte-identical stream)
        spec.speculative = Some(1);
        assert!(spec.validate().is_ok());
        for bad in [0, MAX_DRAFT_TOKENS + 1] {
            spec.speculative = Some(bad);
            assert!(spec.validate().is_err(),
                    "draft_tokens={bad} must be rejected");
        }
        spec.speculative = None;
        assert!(spec.validate().is_ok());
        // lowering threads the opt-in into every per-prompt request
        spec.speculative = Some(4);
        let tok = Tokenizer::new();
        assert!(spec
            .to_requests(&tok)
            .iter()
            .all(|r| r.speculative == Some(4)));
    }

    #[test]
    fn score_spec_tokenizes_without_double_bos() {
        let tok = Tokenizer::new();
        let s = ScoreSpec {
            prompts: vec!["ab".into()],
            continuations: vec!["cd".into()],
            prune: PruneSpec::default(),
            single: true,
        };
        assert!(s.validate().is_ok());
        let r = &s.to_requests(&tok)[0];
        assert_eq!(r.prompt.len(), 3, "BOS + 2 bytes");
        assert_eq!(r.continuation.len(), 2, "no BOS on the continuation");
    }

    #[test]
    fn batched_score_pairs_rows_by_index() {
        let tok = Tokenizer::new();
        let mut s = ScoreSpec {
            prompts: vec!["ab".into(), "xyz".into()],
            continuations: vec!["cd".into(), "w".into()],
            prune: PruneSpec::default(),
            single: false,
        };
        assert!(s.validate().is_ok());
        let rows = s.to_requests(&tok);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].continuation.len(), 2);
        assert_eq!(rows[1].prompt.len(), 4, "BOS + 3 bytes");
        assert_eq!(rows[1].continuation.len(), 1);
        // mismatched row counts are an admission error
        s.continuations.pop();
        assert!(s.validate().is_err());
        // empty rows too
        s.continuations = vec!["cd".into(), String::new()];
        assert!(s.validate().is_err());
    }
}
