//! v2 request parsing + field validation.
//!
//! `parse_request` is the single entry point for both protocol versions:
//! it dispatches on the `"v"` envelope field (absent = v1, handled by
//! the compat shim in `compat.rs`). Fields that are present but of the
//! wrong type are structured `invalid_request` errors, never silent
//! defaults.

use crate::api::compat;
use crate::api::error::{ApiError, ErrorCode};
use crate::api::types::{
    GenerateSpec, PruneMethod, PruneSpec, Request, SamplingSpec, ScoreSpec,
    SelectionStrategy, PROTOCOL_VERSION,
};
use crate::json::Value;

/// Protocol version of a request line (absent `"v"` = 1). Best-effort —
/// used by the server to pick error FRAMING; `parse_request` does the
/// strict check and rejects a malformed `"v"` instead of falling back.
pub fn request_version(v: &Value) -> u64 {
    v.get("v")
        .and_then(Value::as_i64)
        .map(|x| x.max(0) as u64)
        .unwrap_or(1)
}

/// Parse one request line (any version) into a typed, validated
/// [`Request`]. A present-but-non-integer `"v"` (e.g. `"v":"2"` or
/// `"v":2.5`) is an `invalid_request`, never a silent v1 fallback — the
/// fallback would ignore the request's v2 `prune`/`sampling` objects
/// and serve something the client did not ask for.
pub fn parse_request(v: &Value) -> Result<Request, ApiError> {
    let version = match v.get("v") {
        None => 1,
        Some(x) => x
            .as_i64()
            .filter(|&n| n >= 0)
            .map(|n| n as u64)
            .ok_or_else(|| {
                ApiError::invalid("v must be a non-negative integer")
            })?,
    };
    match version {
        1 => compat::parse_v1(v),
        2 => parse_v2(v),
        other => Err(ApiError::new(
            ErrorCode::UnsupportedVersion,
            format!(
                "protocol version {other} not supported (this server \
                 speaks v1 and v{PROTOCOL_VERSION})"
            ),
        )),
    }
}

fn parse_v2(v: &Value) -> Result<Request, ApiError> {
    match str_field(v, "op")? {
        None => Err(ApiError::invalid("missing op")),
        Some("generate") => Ok(Request::Generate(generate_spec(v)?)),
        Some("score") => Ok(Request::Score(score_spec(v)?)),
        Some("cancel") => {
            let id = u64_field(v, "id")?
                .ok_or_else(|| ApiError::invalid("cancel needs an id"))?;
            Ok(Request::Cancel { id })
        }
        Some("health") => Ok(Request::Health),
        Some("metrics") => Ok(Request::Metrics),
        Some("config") => Ok(Request::Config),
        Some("shutdown") => Ok(Request::Shutdown),
        Some(other) => Err(ApiError::new(
            ErrorCode::UnknownOp,
            format!("unknown op {other:?}"),
        )),
    }
}

fn generate_spec(v: &Value) -> Result<GenerateSpec, ApiError> {
    let prompts = match (v.get("prompt"), v.get("prompts")) {
        (Some(_), Some(_)) => {
            return Err(ApiError::invalid(
                "pass either \"prompt\" or \"prompts\", not both",
            ))
        }
        (Some(p), None) => vec![p
            .as_str()
            .ok_or_else(|| ApiError::invalid("prompt must be a string"))?
            .to_string()],
        (None, Some(ps)) => ps
            .as_arr()
            .ok_or_else(|| ApiError::invalid("prompts must be an array"))?
            .iter()
            .map(|p| {
                p.as_str().map(str::to_string).ok_or_else(|| {
                    ApiError::invalid("prompts entries must be strings")
                })
            })
            .collect::<Result<Vec<_>, _>>()?,
        (None, None) => return Err(ApiError::invalid("missing prompt")),
    };
    let sampling = sampling_spec(v.get("sampling"))?;
    if sampling.top_k.is_some() && sampling.top_p.is_some() {
        return Err(ApiError::invalid(
            "sampling.top_k and sampling.top_p are mutually exclusive",
        ));
    }
    let spec = GenerateSpec {
        prompts,
        max_new_tokens: usize_field(v, "max_new_tokens")?.unwrap_or(32),
        prune: prune_spec(v.get("prune"))?,
        sampling,
        stop_at_eos: bool_field(v, "stop_at_eos")?.unwrap_or(true),
        stream: bool_field(v, "stream")?.unwrap_or(false),
        session: str_field(v, "session")?.map(str::to_string),
        speculative: speculative_spec(v.get("speculative"))?,
        v2: true,
    };
    spec.validate()?;
    Ok(spec)
}

/// Parse the `speculative` object (absent = plain decode). The opt-in
/// carries exactly one knob — the requested draft length per spec tick.
fn speculative_spec(v: Option<&Value>)
                    -> Result<Option<usize>, ApiError> {
    let Some(v) = v else { return Ok(None) };
    if matches!(v, Value::Null) {
        return Ok(None);
    }
    if v.as_obj().is_none() {
        return Err(ApiError::invalid("speculative must be an object"));
    }
    usize_field(v, "draft_tokens")?
        .ok_or_else(|| {
            ApiError::invalid("speculative needs draft_tokens")
        })
        .map(Some)
}

fn score_spec(v: &Value) -> Result<ScoreSpec, ApiError> {
    let string_rows = |v: &Value, key: &str, entry: &str| {
        v.as_arr()
            .ok_or_else(|| {
                ApiError::invalid(format!("{key} must be an array"))
            })?
            .iter()
            .map(|p| {
                p.as_str().map(str::to_string).ok_or_else(|| {
                    ApiError::invalid(format!(
                        "{entry} entries must be strings"
                    ))
                })
            })
            .collect::<Result<Vec<_>, _>>()
    };
    let (prompts, single) =
        match (v.get("prompt"), v.get("prompts")) {
            (Some(_), Some(_)) => {
                return Err(ApiError::invalid(
                    "pass either \"prompt\" or \"prompts\", not both",
                ))
            }
            (Some(p), None) => (
                vec![p
                    .as_str()
                    .ok_or_else(|| {
                        ApiError::invalid("prompt must be a string")
                    })?
                    .to_string()],
                true,
            ),
            (None, Some(ps)) => {
                (string_rows(ps, "prompts", "prompts")?, false)
            }
            (None, None) => {
                return Err(ApiError::invalid("missing prompt"))
            }
        };
    let continuations = match (
        v.get("continuation"),
        v.get("continuations"),
        single,
    ) {
        (Some(_), Some(_), _) => {
            return Err(ApiError::invalid(
                "pass either \"continuation\" or \"continuations\", \
                 not both",
            ))
        }
        (Some(c), None, true) => vec![c
            .as_str()
            .ok_or_else(|| {
                ApiError::invalid("continuation must be a string")
            })?
            .to_string()],
        (None, Some(cs), false) => {
            string_rows(cs, "continuations", "continuations")?
        }
        // mixing the singular and array spellings across the two fields
        // is always a shape error
        (Some(_), None, false) | (None, Some(_), true) => {
            return Err(ApiError::invalid(
                "score rows must use matching forms: prompt with \
                 continuation, or prompts with continuations",
            ))
        }
        (None, None, true) => {
            return Err(ApiError::invalid("missing continuation"))
        }
        (None, None, false) => {
            return Err(ApiError::invalid("missing continuations"))
        }
    };
    let spec = ScoreSpec {
        prompts,
        continuations,
        prune: prune_spec(v.get("prune"))?,
        single,
    };
    spec.validate()?;
    Ok(spec)
}

/// Parse the `prune` object (absent = full model).
pub fn prune_spec(v: Option<&Value>) -> Result<PruneSpec, ApiError> {
    let mut spec = PruneSpec::default();
    let Some(v) = v else { return Ok(spec) };
    if v.as_obj().is_none() {
        return Err(ApiError::invalid("prune must be an object"));
    }
    if let Some(m) = v.get("method") {
        let m = m
            .as_str()
            .ok_or_else(|| ApiError::invalid("prune.method must be a string"))?;
        spec.method = match m {
            "none" | "full" => PruneMethod::None,
            "griffin" => PruneMethod::Griffin,
            "magnitude" => PruneMethod::Magnitude,
            "wanda" => PruneMethod::Wanda,
            other => {
                return Err(ApiError::invalid(format!(
                    "unknown prune.method {other:?} (none | griffin | \
                     magnitude | wanda)"
                )))
            }
        };
    }
    if let Some(k) = f64_field(v, "keep")? {
        spec.keep = k;
    }
    if let Some(s) = v.get("strategy") {
        let s = s.as_str().ok_or_else(|| {
            ApiError::invalid("prune.strategy must be a string")
        })?;
        spec.strategy = match s {
            "topk" => SelectionStrategy::TopK,
            "sampling" => SelectionStrategy::Sampling,
            "topk+sampling" => SelectionStrategy::TopKPlusSampling,
            "adaptive-layer" => SelectionStrategy::AdaptiveLayer,
            other => {
                return Err(ApiError::invalid(format!(
                    "unknown prune.strategy {other:?} (topk | sampling | \
                     topk+sampling | adaptive-layer)"
                )))
            }
        };
    }
    if let Some(s) = u64_field(v, "seed")? {
        spec.seed = s;
    }
    Ok(spec)
}

/// Parse the `sampling` object (absent = greedy).
pub fn sampling_spec(v: Option<&Value>) -> Result<SamplingSpec, ApiError> {
    let mut spec = SamplingSpec::default();
    let Some(v) = v else { return Ok(spec) };
    if v.as_obj().is_none() {
        return Err(ApiError::invalid("sampling must be an object"));
    }
    if let Some(t) = f64_field(v, "temperature")? {
        spec.temperature = t as f32;
    }
    spec.top_k = usize_field(v, "top_k")?;
    spec.top_p = f64_field(v, "top_p")?;
    if let Some(s) = u64_field(v, "seed")? {
        spec.seed = s;
    }
    Ok(spec)
}

// -- typed field extraction (present-but-wrong-type is an error) ---------

pub(crate) fn str_field<'a>(v: &'a Value, key: &str)
                            -> Result<Option<&'a str>, ApiError> {
    match v.get(key) {
        None | Some(Value::Null) => Ok(None),
        Some(x) => x.as_str().map(Some).ok_or_else(|| {
            ApiError::invalid(format!("{key} must be a string"))
        }),
    }
}

pub(crate) fn f64_field(v: &Value, key: &str)
                        -> Result<Option<f64>, ApiError> {
    match v.get(key) {
        None | Some(Value::Null) => Ok(None),
        Some(x) => x.as_f64().map(Some).ok_or_else(|| {
            ApiError::invalid(format!("{key} must be a number"))
        }),
    }
}

pub(crate) fn usize_field(v: &Value, key: &str)
                          -> Result<Option<usize>, ApiError> {
    match v.get(key) {
        None | Some(Value::Null) => Ok(None),
        Some(x) => x.as_usize().map(Some).ok_or_else(|| {
            ApiError::invalid(format!(
                "{key} must be a non-negative integer"
            ))
        }),
    }
}

pub(crate) fn u64_field(v: &Value, key: &str)
                        -> Result<Option<u64>, ApiError> {
    match v.get(key) {
        None | Some(Value::Null) => Ok(None),
        Some(x) => x
            .as_i64()
            .filter(|&n| n >= 0)
            .map(|n| n as u64)
            .map(Some)
            .ok_or_else(|| {
                ApiError::invalid(format!(
                    "{key} must be a non-negative integer"
                ))
            }),
    }
}

pub(crate) fn bool_field(v: &Value, key: &str)
                         -> Result<Option<bool>, ApiError> {
    match v.get(key) {
        None | Some(Value::Null) => Ok(None),
        Some(x) => x.as_bool().map(Some).ok_or_else(|| {
            ApiError::invalid(format!("{key} must be a boolean"))
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    fn parse(line: &str) -> Result<Request, ApiError> {
        parse_request(&json::parse(line).unwrap())
    }

    #[test]
    fn v2_generate_with_orthogonal_axes() {
        let r = parse(
            r#"{"v":2,"op":"generate","prompt":"hi","max_new_tokens":8,
                "prune":{"method":"griffin","keep":0.75,
                         "strategy":"sampling","seed":3},
                "sampling":{"temperature":0.8,"top_k":4,"seed":9}}"#,
        )
        .unwrap();
        let Request::Generate(g) = r else { panic!("not generate") };
        assert_eq!(g.prompts, vec!["hi"]);
        assert_eq!(g.max_new_tokens, 8);
        assert_eq!(g.prune.method, PruneMethod::Griffin);
        assert_eq!(g.prune.keep, 0.75);
        assert_eq!(g.prune.strategy, SelectionStrategy::Sampling);
        assert_eq!(g.prune.seed, 3);
        assert_eq!(g.sampling.top_k, Some(4));
        assert_eq!(g.sampling.seed, 9);
        assert!(g.v2);
    }

    #[test]
    fn v2_adaptive_layer_strategy_parses() {
        let r = parse(
            r#"{"v":2,"op":"generate","prompt":"hi",
                "prune":{"method":"griffin","keep":0.5,
                         "strategy":"adaptive-layer"}}"#,
        )
        .unwrap();
        let Request::Generate(g) = r else { panic!("not generate") };
        assert_eq!(g.prune.strategy, SelectionStrategy::AdaptiveLayer);
        // keep bounds apply to adaptive-layer like every strategy
        let e = parse(
            r#"{"v":2,"op":"generate","prompt":"hi",
                "prune":{"method":"griffin","keep":1.5,
                         "strategy":"adaptive-layer"}}"#,
        )
        .unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidRequest);
        // score rides the same prune axis
        let r = parse(
            r#"{"v":2,"op":"score","prompt":"ab","continuation":"cd",
                "prune":{"method":"griffin","keep":0.5,
                         "strategy":"adaptive-layer"}}"#,
        )
        .unwrap();
        let Request::Score(s) = r else { panic!("not score") };
        assert_eq!(s.prune.strategy, SelectionStrategy::AdaptiveLayer);
    }

    #[test]
    fn v2_validation_rejections() {
        let cases = [
            // unknown method
            r#"{"v":2,"op":"generate","prompt":"x",
                "prune":{"method":"nope"}}"#,
            // keep out of range
            r#"{"v":2,"op":"generate","prompt":"x",
                "prune":{"method":"griffin","keep":0.0}}"#,
            r#"{"v":2,"op":"generate","prompt":"x",
                "prune":{"method":"wanda","keep":1.5}}"#,
            // negative temperature
            r#"{"v":2,"op":"generate","prompt":"x",
                "sampling":{"temperature":-1}}"#,
            // top_p out of range
            r#"{"v":2,"op":"generate","prompt":"x",
                "sampling":{"temperature":0.8,"top_p":1.5}}"#,
            // top_k and top_p together
            r#"{"v":2,"op":"generate","prompt":"x",
                "sampling":{"temperature":0.8,"top_k":4,"top_p":0.9}}"#,
            // unknown strategy
            r#"{"v":2,"op":"generate","prompt":"x",
                "prune":{"method":"griffin","strategy":"magic"}}"#,
            // wrong session type
            r#"{"v":2,"op":"generate","prompt":"x","session":7}"#,
            // wrong field type
            r#"{"v":2,"op":"generate","prompt":"x","max_new_tokens":"4"}"#,
            // zero budget
            r#"{"v":2,"op":"generate","prompt":"x","max_new_tokens":0}"#,
            // prompt and prompts together
            r#"{"v":2,"op":"generate","prompt":"x","prompts":["y"]}"#,
        ];
        for line in cases {
            let e = parse(line).unwrap_err();
            assert_eq!(
                e.code,
                ErrorCode::InvalidRequest,
                "line {line} gave {e:?}"
            );
        }
    }

    #[test]
    fn v2_batched_generate_parses() {
        let r = parse(
            r#"{"v":2,"op":"generate","prompts":["a","b","c"]}"#,
        )
        .unwrap();
        let Request::Generate(g) = r else { panic!() };
        assert_eq!(g.prompts.len(), 3);
        assert!(!g.stream);
        assert!(g.session.is_none());
        // batched streaming is a supported surface (per-index events)
        let r = parse(
            r#"{"v":2,"op":"generate","prompts":["a","b"],"stream":true}"#,
        )
        .unwrap();
        let Request::Generate(g) = r else { panic!() };
        assert!(g.stream);
    }

    #[test]
    fn v2_speculative_axis_parses() {
        let r = parse(
            r#"{"v":2,"op":"generate","prompt":"hi",
                "speculative":{"draft_tokens":4}}"#,
        )
        .unwrap();
        let Request::Generate(g) = r else { panic!() };
        assert_eq!(g.speculative, Some(4));
        // absent = plain decode
        let r = parse(r#"{"v":2,"op":"generate","prompt":"hi"}"#).unwrap();
        let Request::Generate(g) = r else { panic!() };
        assert_eq!(g.speculative, None);
        // shape errors are structured rejections
        for line in [
            r#"{"v":2,"op":"generate","prompt":"x","speculative":4}"#,
            r#"{"v":2,"op":"generate","prompt":"x","speculative":{}}"#,
            r#"{"v":2,"op":"generate","prompt":"x",
                "speculative":{"draft_tokens":0}}"#,
            r#"{"v":2,"op":"generate","prompt":"x",
                "speculative":{"draft_tokens":-2}}"#,
            r#"{"v":2,"op":"generate","prompt":"x",
                "speculative":{"draft_tokens":"4"}}"#,
        ] {
            let e = parse(line).unwrap_err();
            assert_eq!(e.code, ErrorCode::InvalidRequest, "line {line}");
        }
    }

    #[test]
    fn v2_batched_score_parses() {
        let r = parse(
            r#"{"v":2,"op":"score","prompts":["ab","cd"],
                "continuations":["x","y"]}"#,
        )
        .unwrap();
        let Request::Score(s) = r else { panic!() };
        assert_eq!(s.prompts.len(), 2);
        assert!(!s.single);
        // singular form still parses and keeps the one-line response
        let r = parse(
            r#"{"v":2,"op":"score","prompt":"ab","continuation":"x"}"#,
        )
        .unwrap();
        let Request::Score(s) = r else { panic!() };
        assert!(s.single);
        for line in [
            // row-count mismatch
            r#"{"v":2,"op":"score","prompts":["a","b"],
                "continuations":["x"]}"#,
            // mixed singular/array spellings
            r#"{"v":2,"op":"score","prompt":"a",
                "continuations":["x"]}"#,
            r#"{"v":2,"op":"score","prompts":["a"],
                "continuation":"x"}"#,
            // both spellings of the same field
            r#"{"v":2,"op":"score","prompt":"a","prompts":["b"],
                "continuation":"x"}"#,
            // empty batch
            r#"{"v":2,"op":"score","prompts":[],"continuations":[]}"#,
            // non-string rows
            r#"{"v":2,"op":"score","prompts":[1],
                "continuations":["x"]}"#,
        ] {
            let e = parse(line).unwrap_err();
            assert_eq!(e.code, ErrorCode::InvalidRequest, "line {line}");
        }
    }

    #[test]
    fn v2_session_affinity_key_parses() {
        let r = parse(
            r#"{"v":2,"op":"generate","prompt":"x","session":"user-9"}"#,
        )
        .unwrap();
        let Request::Generate(g) = r else { panic!() };
        assert_eq!(g.session.as_deref(), Some("user-9"));
    }

    #[test]
    fn v2_other_ops() {
        assert!(matches!(
            parse(r#"{"v":2,"op":"cancel","id":7}"#).unwrap(),
            Request::Cancel { id: 7 }
        ));
        assert!(matches!(
            parse(r#"{"v":2,"op":"health"}"#).unwrap(),
            Request::Health
        ));
        assert!(matches!(
            parse(r#"{"v":2,"op":"score","prompt":"ab",
                      "continuation":"cd"}"#)
                .unwrap(),
            Request::Score(_)
        ));
        let e = parse(r#"{"v":2,"op":"cancel"}"#).unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidRequest);
        // negative ids/seeds are rejected, never wrapped to huge u64s
        let e = parse(r#"{"v":2,"op":"cancel","id":-1}"#).unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidRequest);
        let e = parse(
            r#"{"v":2,"op":"generate","prompt":"x",
                "prune":{"method":"griffin","seed":-3}}"#,
        )
        .unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidRequest);
        let e = parse(r#"{"v":2,"op":"wat"}"#).unwrap_err();
        assert_eq!(e.code, ErrorCode::UnknownOp);
    }

    #[test]
    fn unsupported_version_is_structured() {
        let e = parse(r#"{"v":3,"op":"generate","prompt":"x"}"#)
            .unwrap_err();
        assert_eq!(e.code, ErrorCode::UnsupportedVersion);
    }

    #[test]
    fn malformed_version_never_falls_back_to_v1() {
        // a silent v1 fallback would drop the prune/sampling objects and
        // serve a full-model greedy response the client didn't ask for
        for line in [
            r#"{"v":"2","op":"generate","prompt":"x",
                "prune":{"method":"griffin"}}"#,
            r#"{"v":2.5,"op":"generate","prompt":"x"}"#,
            r#"{"v":-1,"op":"generate","prompt":"x"}"#,
            r#"{"v":true,"op":"generate","prompt":"x"}"#,
        ] {
            let e = parse(line).unwrap_err();
            assert_eq!(e.code, ErrorCode::InvalidRequest, "line {line}");
        }
    }

    #[test]
    fn score_via_v1_is_unknown_op() {
        // score is a v2 op; v1 lines never carried it
        let e = parse(r#"{"op":"score","prompt":"a","continuation":"b"}"#)
            .unwrap_err();
        assert_eq!(e.code, ErrorCode::UnknownOp);
    }
}
