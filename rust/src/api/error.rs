//! Structured protocol errors: every failure the server reports carries
//! a stable machine-readable [`ErrorCode`] so clients can branch on the
//! failure class (backpressure vs bad input vs engine fault) without
//! parsing prose.

use crate::coordinator::router::AdmitError;

/// Stable machine-readable error codes (wire value = `as_str`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// request line was not valid JSON
    BadJson,
    /// `"v"` names a protocol version this server does not speak
    UnsupportedVersion,
    /// missing or unrecognized `"op"`
    UnknownOp,
    /// a field failed admission-time validation (unknown prune method,
    /// keep outside (0,1], negative temperature, top_p outside (0,1]...)
    InvalidRequest,
    /// admission queue at capacity — retry later
    QueueFull,
    /// prompt exceeds the model's compiled context
    PromptTooLong,
    /// prompt tokenized to nothing
    EmptyPrompt,
    /// the engine failed while serving this request; co-tenant requests
    /// are unaffected (per-slot fault containment)
    EngineError,
    /// the engine loop went away before the request completed
    EngineDropped,
    /// the request was cancelled before it produced any result (queued
    /// score requests; cancelled generates get a `done` response with
    /// `finish:"cancelled"` instead, carrying their partial tokens).
    /// Note: a cancel naming an unknown id is NOT an error — the ack
    /// carries `status:"unknown_id"` (cancel is idempotent).
    Cancelled,
}

impl ErrorCode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::BadJson => "bad_json",
            ErrorCode::UnsupportedVersion => "unsupported_version",
            ErrorCode::UnknownOp => "unknown_op",
            ErrorCode::InvalidRequest => "invalid_request",
            ErrorCode::QueueFull => "queue_full",
            ErrorCode::PromptTooLong => "prompt_too_long",
            ErrorCode::EmptyPrompt => "empty_prompt",
            ErrorCode::EngineError => "engine_error",
            ErrorCode::EngineDropped => "engine_dropped",
            ErrorCode::Cancelled => "cancelled",
        }
    }
}

/// A protocol-level failure: code + human-readable message.
#[derive(Debug, Clone, PartialEq)]
pub struct ApiError {
    pub code: ErrorCode,
    pub message: String,
}

impl ApiError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> ApiError {
        ApiError { code, message: message.into() }
    }

    pub fn invalid(message: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::InvalidRequest, message)
    }
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code.as_str(), self.message)
    }
}

impl std::error::Error for ApiError {}

impl From<&AdmitError> for ApiError {
    fn from(e: &AdmitError) -> ApiError {
        let code = match e {
            AdmitError::QueueFull { .. } => ErrorCode::QueueFull,
            AdmitError::PromptTooLong { .. } => ErrorCode::PromptTooLong,
            AdmitError::EmptyPrompt => ErrorCode::EmptyPrompt,
            AdmitError::NoHealthyShards => ErrorCode::EngineDropped,
        };
        ApiError::new(code, e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_stable_wire_strings() {
        assert_eq!(ErrorCode::QueueFull.as_str(), "queue_full");
        assert_eq!(ErrorCode::InvalidRequest.as_str(), "invalid_request");
        assert_eq!(ErrorCode::EngineError.as_str(), "engine_error");
        assert_eq!(ErrorCode::Cancelled.as_str(), "cancelled");
    }

    #[test]
    fn admit_errors_map_to_codes() {
        let e: ApiError = (&AdmitError::QueueFull { capacity: 4 }).into();
        assert_eq!(e.code, ErrorCode::QueueFull);
        assert!(e.message.contains("capacity 4"));
        let e: ApiError = (&AdmitError::EmptyPrompt).into();
        assert_eq!(e.code, ErrorCode::EmptyPrompt);
    }
}
