//! Structured protocol errors: every failure the server reports carries
//! a stable machine-readable [`ErrorCode`] so clients can branch on the
//! failure class (backpressure vs bad input vs engine fault) without
//! parsing prose.

use crate::coordinator::router::AdmitError;

/// Stable machine-readable error codes (wire value = `as_str`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// request line was not valid JSON
    BadJson,
    /// `"v"` names a protocol version this server does not speak
    UnsupportedVersion,
    /// missing or unrecognized `"op"`
    UnknownOp,
    /// a field failed admission-time validation (unknown prune method,
    /// keep outside (0,1], negative temperature, top_p outside (0,1]...)
    InvalidRequest,
    /// admission queue at capacity — retry later
    QueueFull,
    /// the fleet is shedding load under overload pressure — retryable;
    /// the error line carries a `retry_after_ms` hint
    Overloaded,
    /// every engine shard is dead or parked — nothing can serve work
    /// until an operator intervenes (distinct from the transient
    /// `queue_full`/`overloaded` backpressure classes)
    Unavailable,
    /// prompt exceeds the model's compiled context
    PromptTooLong,
    /// prompt tokenized to nothing
    EmptyPrompt,
    /// the engine failed while serving this request; co-tenant requests
    /// are unaffected (per-slot fault containment)
    EngineError,
    /// the engine loop went away before the request completed
    EngineDropped,
    /// the request was cancelled before it produced any result (queued
    /// score requests; cancelled generates get a `done` response with
    /// `finish:"cancelled"` instead, carrying their partial tokens).
    /// Note: a cancel naming an unknown id is NOT an error — the ack
    /// carries `status:"unknown_id"` (cancel is idempotent).
    Cancelled,
}

impl ErrorCode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::BadJson => "bad_json",
            ErrorCode::UnsupportedVersion => "unsupported_version",
            ErrorCode::UnknownOp => "unknown_op",
            ErrorCode::InvalidRequest => "invalid_request",
            ErrorCode::QueueFull => "queue_full",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::Unavailable => "unavailable",
            ErrorCode::PromptTooLong => "prompt_too_long",
            ErrorCode::EmptyPrompt => "empty_prompt",
            ErrorCode::EngineError => "engine_error",
            ErrorCode::EngineDropped => "engine_dropped",
            ErrorCode::Cancelled => "cancelled",
        }
    }
}

/// A protocol-level failure: code + human-readable message.
/// `retry_after_ms` is `Some` only for retryable backpressure errors
/// (`overloaded`); when set, the wire error line carries it.
#[derive(Debug, Clone, PartialEq)]
pub struct ApiError {
    pub code: ErrorCode,
    pub message: String,
    pub retry_after_ms: Option<u64>,
}

impl ApiError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> ApiError {
        ApiError { code, message: message.into(), retry_after_ms: None }
    }

    pub fn invalid(message: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::InvalidRequest, message)
    }
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code.as_str(), self.message)
    }
}

impl std::error::Error for ApiError {}

impl From<&AdmitError> for ApiError {
    fn from(e: &AdmitError) -> ApiError {
        let code = match e {
            AdmitError::QueueFull { .. } => ErrorCode::QueueFull,
            AdmitError::Overloaded { .. } => ErrorCode::Overloaded,
            AdmitError::PromptTooLong { .. } => ErrorCode::PromptTooLong,
            AdmitError::EmptyPrompt => ErrorCode::EmptyPrompt,
            AdmitError::NoHealthyShards => ErrorCode::Unavailable,
        };
        let mut err = ApiError::new(code, e.to_string());
        if let AdmitError::Overloaded { retry_after_ms } = e {
            err.retry_after_ms = Some(*retry_after_ms);
        }
        err
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_stable_wire_strings() {
        assert_eq!(ErrorCode::QueueFull.as_str(), "queue_full");
        assert_eq!(ErrorCode::InvalidRequest.as_str(), "invalid_request");
        assert_eq!(ErrorCode::EngineError.as_str(), "engine_error");
        assert_eq!(ErrorCode::Cancelled.as_str(), "cancelled");
        assert_eq!(ErrorCode::Overloaded.as_str(), "overloaded");
        assert_eq!(ErrorCode::Unavailable.as_str(), "unavailable");
    }

    #[test]
    fn admit_errors_map_to_codes() {
        let e: ApiError = (&AdmitError::QueueFull { capacity: 4 }).into();
        assert_eq!(e.code, ErrorCode::QueueFull);
        assert!(e.message.contains("capacity 4"));
        assert_eq!(e.retry_after_ms, None);
        let e: ApiError = (&AdmitError::EmptyPrompt).into();
        assert_eq!(e.code, ErrorCode::EmptyPrompt);
    }

    #[test]
    fn overload_and_outage_map_to_retryable_codes() {
        let e: ApiError =
            (&AdmitError::Overloaded { retry_after_ms: 120 }).into();
        assert_eq!(e.code, ErrorCode::Overloaded);
        assert_eq!(e.retry_after_ms, Some(120));
        assert!(e.message.contains("120"));
        // a fleet with no live shard is an outage, not backpressure:
        // clients must see `unavailable`, never `engine_dropped`
        let e: ApiError = (&AdmitError::NoHealthyShards).into();
        assert_eq!(e.code, ErrorCode::Unavailable);
        assert_eq!(e.retry_after_ms, None);
    }
}
