//! Sparsity explorer: for one prompt, walk the compiled k-bucket ladder
//! and print generation quality + latency at each FF width — the
//! interactive version of the paper's Figure 4 trade-off.
//!
//!     cargo run --release --example sparsity_explorer [model] ["prompt"]

use griffin::coordinator::engine::{Engine, Mode};
use griffin::coordinator::sequence::GenRequest;
use griffin::eval;
use griffin::test_support::artifact_path;
use griffin::tokenizer::Tokenizer;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1)
        .unwrap_or_else(|| "small-swiglu".to_string());
    let prompt = std::env::args().nth(2).unwrap_or_else(|| {
        "= doc 3 : hills =\nthe old hill shadows the green meadow . \
         the green meadow feeds the old hill . the old hill"
            .to_string()
    });
    let dir = artifact_path(&model);
    let trained = griffin::config::Manifest::load(&dir)?
        .trained_weights_file
        .is_some();
    let mut engine = Engine::load(&dir, trained)?;
    let cfg = engine.config().clone();
    let tok = Tokenizer::new();

    // reference generation from the full model
    let mut req =
        GenRequest::greedy(1, tok.encode_with_bos(&prompt), 48, Mode::Full);
    req.stop_at_eos = false;
    let full = engine.generate(&req)?;
    println!("prompt: {prompt}\n");
    println!(
        "{:<10} {:>8} {:>12} {:>12} {:>10}  completion",
        "keep", "k", "decode_ms", "agree@48", "rouge1"
    );
    println!(
        "{:<10} {:>8} {:>12.0} {:>12} {:>10}  {}",
        "full",
        cfg.d_ff,
        full.decode_ms,
        "1.00",
        "1.00",
        full.text.replace('\n', "\\n")
    );

    for &k in cfg.keep_ks.iter().rev() {
        if k >= cfg.d_ff {
            continue;
        }
        let keep = k as f64 / cfg.d_ff as f64;
        let mut req = GenRequest::greedy(
            1, tok.encode_with_bos(&prompt), 48, Mode::griffin(keep));
        req.stop_at_eos = false;
        let resp = engine.generate(&req)?;
        // token-level agreement with the full model's generation
        let agree = resp
            .tokens
            .iter()
            .zip(&full.tokens)
            .take_while(|(a, b)| a == b)
            .count() as f64
            / full.tokens.len() as f64;
        let r1 = eval::rouge_n(&resp.text, &full.text, 1).f1;
        println!(
            "{:<10.3} {:>8} {:>12.0} {:>12.2} {:>10.2}  {}",
            keep,
            k,
            resp.decode_ms,
            agree,
            r1,
            resp.text.replace('\n', "\\n")
        );
    }
    println!(
        "\nagree@48 = length of the shared greedy prefix with the full \
         model;\nrouge1 vs the full model's own completion (not a gold \
         reference)."
    );
    Ok(())
}
