//! Quickstart: load a model, generate with the full model and with
//! GRIFFIN at 50% FF sparsity, compare output + latency.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the paper's Figure-3 flow in ~40 lines of user code:
//! prompt phase (full model, statistic s collected) → top-k expert
//! selection → gather → generation phase with the pruned FF blocks.

use griffin::coordinator::engine::{Engine, Mode};
use griffin::coordinator::sequence::GenRequest;
use griffin::test_support::artifact_path;
use griffin::tokenizer::Tokenizer;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1)
        .unwrap_or_else(|| "small-swiglu".to_string());
    let dir = artifact_path(&model);
    if !dir.join("manifest.json").exists() {
        anyhow::bail!("run `make artifacts` first (no artifacts for {model})");
    }
    // trained weights if the trainer has produced them
    let trained = griffin::config::Manifest::load(&dir)?
        .trained_weights_file
        .is_some();
    let mut engine = Engine::load(&dir, trained)?;
    println!(
        "model {model}: {:.2}M params, activation={}, d_ff={}",
        engine.config().param_count as f64 / 1e6,
        engine.config().activation,
        engine.config().d_ff
    );

    let tok = Tokenizer::new();
    let prompt = "= doc 7 : rivers =\nthe quiet river joins the deep lake . \
                  the deep lake feeds the old mill . the quiet river";

    for mode in [Mode::Full, Mode::griffin(0.5)] {
        let req = GenRequest::greedy(
            1, tok.encode_with_bos(prompt), 48, mode);
        let resp = engine.generate(&req)?;
        println!("\n--- {} (active params {:.2}M) ---",
            mode.label(),
            engine.config().active_params_at_k(
                resp.k_used.unwrap_or(engine.config().d_ff)) as f64 / 1e6);
        println!("{}", resp.text);
        println!(
            "prefill {:.0}ms | select {:.1}ms | decode {:.0}ms",
            resp.prefill_ms, resp.select_ms, resp.decode_ms
        );
    }
    Ok(())
}
