//! End-to-end serving driver (DESIGN.md §5 / EXPERIMENTS.md §E2E):
//! starts the TCP server, replays a request trace from concurrent client
//! threads against the GRIFFIN engine, and reports latency/throughput —
//! proving all layers compose: JSON protocol → router/backpressure →
//! continuous-batching slot scheduler → prefill/select/gather/decode
//! over PJRT. Half the clients use the streaming protocol, so
//! time-to-first-token is measured both client-side (first token line on
//! the wire) and engine-side (the ttft histogram).
//!
//!     cargo run --release --example serve_e2e [model] [n_requests]
//!
//! Defaults: small-swiglu (trained), 24 requests, mixed prompt lengths,
//! half full-model / half GRIFFIN@50%.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use griffin::coordinator::engine::Engine;
use griffin::json::{n, obj, s, Value};
use griffin::test_support::artifact_path;
use griffin::util::percentile;
use griffin::workload::trace;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1)
        .unwrap_or_else(|| "small-swiglu".to_string());
    let n_requests: usize = std::env::args()
        .nth(2)
        .and_then(|a| a.parse().ok())
        .unwrap_or(24);
    let dir = artifact_path(&model);
    let trained = griffin::config::Manifest::load(&dir)?
        .trained_weights_file
        .is_some();
    let engine = Engine::load(&dir, trained)?;
    let cfg = engine.config().clone();
    let metrics = engine.metrics.clone();

    let (handle, mut scheduler, waiters) =
        griffin::server::start_listener(engine, "127.0.0.1:0", 256)?;
    let addr = handle.addr.to_string();
    println!("serving {model} on {addr}; replaying {n_requests} requests");

    let reqs = trace::generate(&trace::TraceSpec {
        seed: 42,
        n_requests,
        prompt_len: cfg.prefill_buckets[cfg.prefill_buckets.len() / 2],
        gen_len: 24,
        mean_gap_ms: 0,
        mixed_lengths: true,
    });

    let done = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    let mut client_threads = Vec::new();
    let latencies = Arc::new(std::sync::Mutex::new(Vec::<f64>::new()));
    let ttfts = Arc::new(std::sync::Mutex::new(Vec::<f64>::new()));
    let tokens_out = Arc::new(AtomicUsize::new(0));
    // 4 concurrent client connections, each sending its slice of the
    // trace; even-numbered connections use the streaming protocol
    for (ci, chunk) in reqs.chunks(n_requests.div_ceil(4)).enumerate() {
        let addr = addr.clone();
        let chunk: Vec<trace::TraceRequest> = chunk.to_vec();
        let done = done.clone();
        let latencies = latencies.clone();
        let ttfts = ttfts.clone();
        let tokens_out = tokens_out.clone();
        let streaming = ci % 2 == 0;
        client_threads.push(std::thread::spawn(move || {
            let tok = griffin::tokenizer::Tokenizer::new();
            let mut client =
                griffin::server::Client::connect(&addr).unwrap();
            for (i, r) in chunk.iter().enumerate() {
                let mode =
                    if (ci + i) % 2 == 0 { "griffin" } else { "full" };
                let prompt_text = tok.decode(&r.prompt);
                let t = Instant::now();
                let resp = if streaming {
                    let mut first_token_ms = None;
                    let mut n_tokens = 0usize;
                    let resp = client
                        .generate_stream(
                            &prompt_text,
                            r.max_new_tokens,
                            mode,
                            |_tok_event| {
                                if first_token_ms.is_none() {
                                    first_token_ms = Some(
                                        t.elapsed().as_secs_f64() * 1e3);
                                }
                                n_tokens += 1;
                            },
                        )
                        .unwrap();
                    if let Some(ms) = first_token_ms {
                        ttfts.lock().unwrap().push(ms);
                    }
                    tokens_out.fetch_add(n_tokens, Ordering::Relaxed);
                    resp
                } else {
                    // non-streaming connections speak the typed v2
                    // protocol: the pruning knob is an orthogonal object,
                    // not a mode string (streaming ones stay on v1 to
                    // keep the compat shim exercised end-to-end)
                    let prune = if mode == "griffin" {
                        obj(vec![
                            ("method", s("griffin")),
                            ("keep", n(0.5)),
                            ("strategy", s("topk")),
                        ])
                    } else {
                        obj(vec![("method", s("none"))])
                    };
                    let resp = client
                        .call(&obj(vec![
                            ("v", n(2.0)),
                            ("op", s("generate")),
                            ("prompt", s(&prompt_text)),
                            ("max_new_tokens", n(r.max_new_tokens as f64)),
                            ("prune", prune),
                        ]))
                        .unwrap();
                    if let Some(Value::Arr(toks)) =
                        resp.get("tokens").cloned()
                    {
                        tokens_out.fetch_add(toks.len(), Ordering::Relaxed);
                    }
                    resp
                };
                let dt = t.elapsed().as_secs_f64() * 1e3;
                latencies.lock().unwrap().push(dt);
                assert_eq!(
                    resp.get("op").and_then(Value::as_str),
                    Some("generate"),
                    "bad reply: {resp:?}"
                );
                done.fetch_add(1, Ordering::Relaxed);
            }
        }));
    }

    // engine loop on the main thread until all requests completed
    {
        let waiters = waiters.clone();
        let done = done.clone();
        scheduler.serve(
            move |ev| griffin::server::forward(&waiters, ev),
            &move || done.load(Ordering::Relaxed) >= n_requests,
        )?;
    }
    for t in client_threads {
        t.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    handle.shutdown();

    let lat = latencies.lock().unwrap().clone();
    let ttft = ttfts.lock().unwrap().clone();
    let toks = tokens_out.load(Ordering::Relaxed);
    println!("\n=== end-to-end serving report ===");
    println!("requests      : {n_requests} ({} ok)", lat.len());
    println!("wall time     : {wall:.2}s");
    println!("throughput    : {:.2} req/s, {:.1} gen tok/s",
             n_requests as f64 / wall, toks as f64 / wall);
    println!("latency p50   : {:.0} ms", percentile(&lat, 50.0));
    println!("latency p90   : {:.0} ms", percentile(&lat, 90.0));
    println!("latency p99   : {:.0} ms", percentile(&lat, 99.0));
    if !ttft.is_empty() {
        println!("client TTFT p50: {:.0} ms ({} streamed)",
                 percentile(&ttft, 50.0), ttft.len());
    }
    let snap = metrics.ttft.snapshot();
    println!("engine TTFT p50: {:.0} ms (count {})",
             snap.p50_us / 1e3, snap.count);
    let snap = metrics.inter_token_latency.snapshot();
    println!("inter-token p50: {:.2} ms (count {})",
             snap.p50_us / 1e3, snap.count);
    let snap = metrics.prefill_latency.snapshot();
    println!("prefill p50   : {:.0} ms (count {})",
             snap.p50_us / 1e3, snap.count);
    let snap = metrics.decode_step_latency.snapshot();
    println!("decode-step p50: {:.2} ms (count {})",
             snap.p50_us / 1e3, snap.count);
    let occ = metrics.slot_occupancy.snapshot();
    println!("slot occupancy: mean {:.2} of {} (over {} ticks)",
             occ.mean_us, metrics.slots_total.get(), occ.count);

    // machine-readable record for EXPERIMENTS.md
    let report = obj(vec![
        ("model", s(&model)),
        ("requests", n(n_requests as f64)),
        ("wall_s", n(wall)),
        ("req_per_s", n(n_requests as f64 / wall)),
        ("gen_tok_per_s", n(toks as f64 / wall)),
        ("latency_p50_ms", n(percentile(&lat, 50.0))),
        ("latency_p90_ms", n(percentile(&lat, 90.0))),
        (
            "client_ttft_p50_ms",
            if ttft.is_empty() {
                Value::Null
            } else {
                n(percentile(&ttft, 50.0))
            },
        ),
        ("engine_ttft_p50_us", n(metrics.ttft.snapshot().p50_us)),
        ("slot_occupancy_mean", n(occ.mean_us)),
    ]);
    let path = griffin::test_support::results_path(
        &format!("e2e_serving_{model}.json"));
    std::fs::write(&path, griffin::json::to_string(&report))?;
    println!("-> {}", path.display());
    Ok(())
}
