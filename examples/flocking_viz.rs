//! Flocking visualizer: renders the paper's Figure-1 heatmap as ASCII in
//! the terminal — relative FF activation magnitudes for a sequence, with
//! the vertical streaks (= flocking) visible directly.
//!
//!     cargo run --release --example flocking_viz [model] [layer]

use griffin::coordinator::engine::Engine;
use griffin::runtime::{DeviceTensor, Substrate};
use griffin::test_support::artifact_path;
use griffin::tokenizer::Tokenizer;
use griffin::workload::{corpus, tasks};

const SHADES: &[char] = &[' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1)
        .unwrap_or_else(|| "small-swiglu".to_string());
    let dir = artifact_path(&model);
    let trained = griffin::config::Manifest::load(&dir)?
        .trained_weights_file
        .is_some();
    let engine = Engine::load(&dir, trained)?;
    let cfg = engine.config().clone();
    let layer: usize = std::env::args()
        .nth(2)
        .and_then(|a| a.parse().ok())
        .unwrap_or(cfg.n_layers / 2);

    let spec = engine
        .session
        .manifest()
        .executables
        .values()
        .find(|e| e.kind == "activations")
        .expect("activations artifact (make artifacts)")
        .clone();
    let s_bucket = spec.seq.unwrap();

    let tok = Tokenizer::new();
    let text = corpus::corpus(tasks::HELDOUT_SEED + 3, 2, 24);
    let ids = tok.encode(&text);
    let (row, real) = engine.tokenizer.fit(&ids, s_bucket);
    let toks = engine.session.upload_i32(&[1, s_bucket], &row)?;
    let lens = engine.session.upload_i32(&[1], &[real as i32])?;
    let mut args: Vec<&DeviceTensor> = engine.weights.ordered();
    args.push(&toks);
    args.push(&lens);
    let outs = engine.session.run(&spec.name, &args)?;
    let zbar = outs[0].to_f32()?;
    let f = cfg.d_ff;

    // terminal raster: rows = tokens (subsampled), cols = neurons
    let max_rows = 48usize;
    let max_cols = 120usize;
    let row_step = (real / max_rows).max(1);
    let col_step = (f / max_cols).max(1);
    // normalize by the global max for a stable ramp
    let mut vmax = 0f32;
    for t in 0..real {
        for j in 0..f {
            vmax = vmax.max(zbar[(layer * s_bucket + t) * f + j]);
        }
    }
    println!(
        "relative FF activation magnitudes — {model}, layer {layer} \
         ({} tokens x {} neurons; darker = stronger)\n",
        real, f
    );
    for t in (0..real).step_by(row_step).take(max_rows) {
        let mut line = String::with_capacity(max_cols);
        for j in (0..f).step_by(col_step).take(max_cols) {
            // max-pool the block so streaks survive subsampling
            let mut v = 0f32;
            for tt in t..(t + row_step).min(real) {
                for jj in j..(j + col_step).min(f) {
                    v = v.max(zbar[(layer * s_bucket + tt) * f + jj]);
                }
            }
            let idx = ((v / vmax).powf(0.5) * (SHADES.len() - 1) as f32)
                .round() as usize;
            line.push(SHADES[idx.min(SHADES.len() - 1)]);
        }
        println!("{line}");
    }
    println!(
        "\nvertical streaks = neurons consistently active across the \
         sequence (flocking, paper §4.1)."
    );
    Ok(())
}
